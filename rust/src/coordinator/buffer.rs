//! Stateful rollout buffer (paper §3.3) — the staleness-aware cache.
//!
//! Each entry tracks one prompt's in-progress sample through its lifecycle:
//! prompt context, current partial trajectory, the behavior-policy
//! log-probs of every generated token, a completion flag, and a lifecycle
//! indicator deciding when the entry is cleared.  The controller's
//! cache-aware loading rule ("no new prompts until all cached prompts are
//! consumed", §3.1) is enforced here via [`RolloutBuffer::all_consumed`].
//!
//! The paper's cache-based off-policy-degree control lives here too: every
//! entry carries the weights version stamped on its lane at dispatch
//! ([`RolloutBuffer::dispatch_stamped`]) alongside the version that
//! sampled its first token, so per-sample version deltas are exact, and
//! [`RolloutBuffer::consume_bounded`] enforces the `--staleness` hard cap
//! at CONSUME time — a sample older than the cap never reaches the
//! trainer, regardless of what the phase machine decided.  First
//! violation: the sample is re-synced (partial discarded, regenerated
//! under current weights); second: dropped untrained.

use crate::rollout::{Request, Rollout};
use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    /// Loaded from the dataloader, never scheduled yet.
    Fresh,
    /// Currently inside the rollout engine (lane or queue).
    InFlight,
    /// Terminated mid-generation; waiting to be rescheduled.
    Scavenged,
    /// Finished; trajectory ready for the trainer.
    Ready,
    /// Fed to the trainer; kept only for accounting until cleared.
    Consumed,
}

#[derive(Debug, Clone)]
pub struct BufferEntry {
    pub rid: u64,
    pub problem_idx: usize,
    pub prompt_id: u64,
    pub prompt: Vec<i32>,
    /// Tokens generated so far (response prefix for scavenged entries,
    /// full response for ready ones).
    pub partial: Vec<i32>,
    /// Sampling-time log-probs, aligned with `partial` (π_old, Eq. 1).
    pub partial_logp: Vec<f32>,
    pub complete: bool,
    pub lifecycle: Lifecycle,
    pub born_version: Option<u64>,
    pub finish_version: u64,
    /// Trainer weights version current when this entry was last dispatched
    /// into a lane ([`RolloutBuffer::dispatch_stamped`]).  `born_version`
    /// is only set once a token is sampled; the dispatch stamp covers the
    /// gap so staleness accounting never has to infer.
    pub dispatch_version: Option<u64>,
    /// Times this entry was bounced by the consume-time staleness cap
    /// ([`RolloutBuffer::consume_bounded`]): 0 = never, 1 = re-synced
    /// once (a second violation drops it).
    pub stale_resyncs: u32,
    pub resumes: u32,
    pub max_new: usize,
    /// Engine-clock time when the entry became Ready (length proxy).
    pub finished_at: f64,
    /// True if harvested clipped (incomplete but trained as-is).
    pub clipped: bool,
}

/// Buffer policy: what happens to interrupted generations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Fully on-policy: discard partial tokens, re-queue the prompt.
    OnPolicy,
    /// Partial: keep tokens + log-probs, resume under the new policy.
    Partial,
}

/// Result of a [`RolloutBuffer::consume_bounded`] harvest: the entries the
/// trainer may actually see, plus the rids bounced by the staleness cap
/// (re-synced back to schedulable, or dropped untrained on a repeat
/// violation).  `entries` preserves the caller's rid order.
#[derive(Debug, Default)]
pub struct BoundedConsume {
    pub entries: Vec<BufferEntry>,
    pub resynced: Vec<u64>,
    pub dropped: Vec<u64>,
}

#[derive(Debug, Default)]
pub struct RolloutBuffer {
    entries: BTreeMap<u64, BufferEntry>,
    next_rid: u64,
}

impl RolloutBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn count(&self, lc: Lifecycle) -> usize {
        self.entries.values().filter(|e| e.lifecycle == lc).count()
    }

    pub fn get(&self, rid: u64) -> Option<&BufferEntry> {
        self.entries.get(&rid)
    }

    /// Load a prompt (one sample thereof); returns its rid.
    pub fn load_prompt(&mut self, problem_idx: usize, prompt_id: u64,
                       prompt: Vec<i32>, max_new: usize) -> u64 {
        let rid = self.next_rid;
        self.next_rid += 1;
        self.entries.insert(rid, BufferEntry {
            rid,
            problem_idx,
            prompt_id,
            prompt,
            partial: Vec::new(),
            partial_logp: Vec::new(),
            complete: false,
            lifecycle: Lifecycle::Fresh,
            born_version: None,
            finish_version: 0,
            dispatch_version: None,
            stale_resyncs: 0,
            resumes: 0,
            max_new,
            finished_at: 0.0,
            clipped: false,
        });
        rid
    }

    /// Entries schedulable right now (Fresh or Scavenged), FIFO by rid.
    pub fn schedulable(&self) -> Vec<u64> {
        self.entries
            .values()
            .filter(|e| matches!(e.lifecycle, Lifecycle::Fresh | Lifecycle::Scavenged))
            .map(|e| e.rid)
            .collect()
    }

    /// Build engine requests for the given rids and mark them in flight.
    pub fn dispatch(&mut self, rids: &[u64]) -> Vec<Request> {
        rids.iter()
            .map(|rid| {
                let e = self.entries.get_mut(rid).expect("dispatch unknown rid");
                assert!(
                    matches!(e.lifecycle, Lifecycle::Fresh | Lifecycle::Scavenged),
                    "dispatching {:?} entry {rid}",
                    e.lifecycle
                );
                e.lifecycle = Lifecycle::InFlight;
                Request {
                    rid: e.rid,
                    problem_idx: e.problem_idx,
                    prompt_id: e.prompt_id,
                    prompt: e.prompt.clone(),
                    resumed: e.partial.clone(),
                    resumed_logp: e.partial_logp.clone(),
                    born_version: e.born_version,
                    resumes: e.resumes,
                    max_new: e.max_new,
                    // stamped by the pool at dispatch (predictor-owned)
                    predicted_len: None,
                }
            })
            .collect()
    }

    /// [`RolloutBuffer::dispatch`] plus an exact version stamp: the
    /// trainer's current weights version is recorded on every lane at
    /// dispatch time, so an entry's off-policy delta is known even before
    /// (or without) its first sampled token.
    pub fn dispatch_stamped(&mut self, rids: &[u64], version: u64) -> Vec<Request> {
        for rid in rids {
            self.entries
                .get_mut(rid)
                .expect("dispatch unknown rid")
                .dispatch_version = Some(version);
        }
        self.dispatch(rids)
    }

    /// Record a scheduler-CLIPPED rollout -> Ready (trained as-is, truncated).
    /// On-policy harvests fill their quota this way (§3.1: "both completed
    /// and partially generated outputs are harvested"); both modes clip at
    /// the group's final wave instead of riding the drain tail.
    pub fn record_clipped(&mut self, r: &Rollout) {
        let e = self.entries.get_mut(&r.request.rid).expect("unknown rid");
        debug_assert_eq!(e.lifecycle, Lifecycle::InFlight);
        e.partial = r.response.clone();
        e.partial_logp = r.logp.clone();
        e.complete = false; // clipped: the model never finished it
        e.clipped = true;
        e.lifecycle = Lifecycle::Ready;
        e.born_version = r.request.born_version;
        e.finish_version = r.finish_version;
        e.finished_at = r.finished_at;
    }

    /// Consume entries WITHOUT training (group-end drops of never-scheduled
    /// prompts — Fig. 2's gray bars).  Returns how many were dropped.
    pub fn consume_untrained(&mut self, rids: &[u64]) -> usize {
        for rid in rids {
            let e = self.entries.get_mut(rid).expect("unknown rid");
            e.lifecycle = Lifecycle::Consumed;
        }
        rids.len()
    }

    /// Record a finished rollout -> Ready.
    pub fn record_finished(&mut self, r: &Rollout) {
        let e = self.entries.get_mut(&r.request.rid).expect("unknown rid");
        debug_assert_eq!(e.lifecycle, Lifecycle::InFlight);
        e.partial = r.response.clone();
        e.partial_logp = r.logp.clone();
        e.complete = true;
        e.lifecycle = Lifecycle::Ready;
        e.born_version = r.request.born_version;
        e.finish_version = r.finish_version;
        e.finished_at = r.finished_at;
    }

    /// Record a scheduler-terminated rollout according to `mode`:
    /// OnPolicy discards the partial tokens (prompt restarts from scratch),
    /// Partial scavenges tokens + log-probs for resumption (§3.2).
    pub fn record_terminated(&mut self, r: &Rollout, mode: Mode) {
        let e = self.entries.get_mut(&r.request.rid).expect("unknown rid");
        debug_assert_eq!(e.lifecycle, Lifecycle::InFlight);
        match mode {
            Mode::OnPolicy => {
                e.partial.clear();
                e.partial_logp.clear();
                e.born_version = None; // restart: next attempt is fresh
            }
            Mode::Partial => {
                e.partial = r.response.clone();
                e.partial_logp = r.logp.clone();
                e.born_version = r.request.born_version;
            }
        }
        // Sync from the request's own counter, not `+= 1` blindly: the
        // engine pool may have preempted-and-resumed this request
        // internally (bumping Request::resumes without a buffer round
        // trip), and the next segment must get a PCG stream no earlier
        // segment has used (stream id = 0xB0 + resumes).
        e.resumes = e.resumes.max(r.request.resumes) + 1;
        e.lifecycle = Lifecycle::Scavenged;
    }

    /// Re-queue a request that was waiting in the engine queue (untouched).
    pub fn record_requeued(&mut self, rid: u64) {
        let e = self.entries.get_mut(&rid).expect("unknown rid");
        debug_assert_eq!(e.lifecycle, Lifecycle::InFlight);
        e.lifecycle = if e.partial.is_empty() {
            Lifecycle::Fresh
        } else {
            Lifecycle::Scavenged
        };
    }

    /// Ready entries in completion order (the length-sorted order the
    /// micro-curriculum consumes).
    pub fn ready_rids(&self) -> Vec<u64> {
        let mut v: Vec<&BufferEntry> = self
            .entries
            .values()
            .filter(|e| e.lifecycle == Lifecycle::Ready)
            .collect();
        v.sort_by(|a, b| a.finished_at.partial_cmp(&b.finished_at).unwrap()
            .then(a.rid.cmp(&b.rid)));
        v.into_iter().map(|e| e.rid).collect()
    }

    /// Consume exactly `rids` (marks Consumed and returns their entries).
    pub fn consume(&mut self, rids: &[u64]) -> Vec<BufferEntry> {
        rids.iter()
            .map(|rid| {
                let e = self.entries.get_mut(rid).expect("consume unknown rid");
                assert_eq!(e.lifecycle, Lifecycle::Ready, "consume non-ready {rid}");
                e.lifecycle = Lifecycle::Consumed;
                e.clone()
            })
            .collect()
    }

    /// Exact off-policy staleness this entry would have if consumed by an
    /// update entering at `train_version` (see [`crate::rl::staleness`]).
    /// The birth version falls back through the dispatch stamp to the
    /// finish version, so entries that never sampled a token still report
    /// an exact (not inferred) delta.
    pub fn staleness_at(&self, rid: u64, train_version: u64) -> Option<u64> {
        self.entries.get(&rid).map(|e| {
            let born = e.born_version.or(e.dispatch_version).unwrap_or(e.finish_version);
            crate::rl::staleness(train_version, born)
        })
    }

    /// [`RolloutBuffer::consume`] under the `--staleness` hard cap: entries
    /// whose version delta against `train_version` is within `cap` are
    /// consumed for training; over-stale entries never reach the trainer.
    /// The first violation re-syncs the entry (partial discarded, back to
    /// schedulable — it regenerates under the current weights); a repeat
    /// violation drops it untrained, so a perpetually-unlucky sample
    /// cannot livelock the group.  `cap: None` = no bound (identical to
    /// [`RolloutBuffer::consume`]).
    pub fn consume_bounded(&mut self, rids: &[u64], train_version: u64,
                           cap: Option<u64>) -> BoundedConsume {
        let Some(cap) = cap else {
            return BoundedConsume {
                entries: self.consume(rids),
                resynced: Vec::new(),
                dropped: Vec::new(),
            };
        };
        let mut out = BoundedConsume {
            entries: Vec::new(),
            resynced: Vec::new(),
            dropped: Vec::new(),
        };
        for rid in rids {
            let e = self.entries.get_mut(rid).expect("consume unknown rid");
            assert_eq!(e.lifecycle, Lifecycle::Ready, "consume non-ready {rid}");
            let born = e.born_version.or(e.dispatch_version).unwrap_or(e.finish_version);
            if crate::rl::staleness(train_version, born) <= cap {
                e.lifecycle = Lifecycle::Consumed;
                out.entries.push(e.clone());
            } else if e.stale_resyncs == 0 {
                // first violation: regenerate under the current weights
                e.stale_resyncs = 1;
                e.partial.clear();
                e.partial_logp.clear();
                e.complete = false;
                e.clipped = false;
                e.born_version = None;
                e.dispatch_version = None;
                e.finished_at = 0.0;
                e.lifecycle = Lifecycle::Scavenged;
                out.resynced.push(*rid);
            } else {
                // repeat offender: drop untrained (bounded retries)
                e.lifecycle = Lifecycle::Consumed;
                out.dropped.push(*rid);
            }
        }
        out
    }

    /// The grouped-rollout barrier: true when every loaded prompt has been
    /// consumed by the trainer (controller may then load the next group).
    pub fn all_consumed(&self) -> bool {
        self.entries
            .values()
            .all(|e| e.lifecycle == Lifecycle::Consumed)
    }

    /// Drop consumed entries (lifecycle end).
    pub fn clear_consumed(&mut self) {
        self.entries.retain(|_, e| e.lifecycle != Lifecycle::Consumed);
    }

    /// Remove entries outright (no-grouped ablation abandons interrupted
    /// generations — the prompt-starvation failure mode Fig. 6a shows).
    pub fn discard(&mut self, rids: &[u64]) {
        for rid in rids {
            self.entries.remove(rid);
        }
    }

    /// Sanity invariant: every entry is in exactly one lifecycle state and
    /// scavenged entries carry log-probs matching their partials.
    pub fn check_invariants(&self) -> Result<(), String> {
        for e in self.entries.values() {
            if e.partial.len() != e.partial_logp.len() {
                return Err(format!(
                    "rid {}: partial len {} != logp len {}",
                    e.rid,
                    e.partial.len(),
                    e.partial_logp.len()
                ));
            }
            if e.lifecycle == Lifecycle::Ready && !e.complete && !e.clipped {
                return Err(format!("rid {}: ready but neither complete nor clipped", e.rid));
            }
            if e.partial.len() > e.max_new {
                return Err(format!(
                    "rid {}: partial {} exceeds max_new {}",
                    e.rid,
                    e.partial.len(),
                    e.max_new
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rollout::Request;

    fn rollout(rid: u64, toks: Vec<i32>, complete: bool, at: f64) -> Rollout {
        let n = toks.len();
        Rollout {
            request: Request {
                rid,
                problem_idx: 0,
                prompt_id: rid,
                prompt: vec![1, 2],
                resumed: vec![],
                resumed_logp: vec![],
                born_version: Some(3),
                resumes: 0,
                max_new: 64,
                predicted_len: None,
            },
            response: toks,
            logp: vec![-0.5; n],
            finish_version: 3,
            complete,
            finished_at: at,
        }
    }

    #[test]
    fn lifecycle_happy_path() {
        let mut buf = RolloutBuffer::new();
        let rid = buf.load_prompt(0, 7, vec![1, 2], 64);
        assert_eq!(buf.count(Lifecycle::Fresh), 1);
        let reqs = buf.dispatch(&[rid]);
        assert_eq!(reqs.len(), 1);
        assert_eq!(buf.count(Lifecycle::InFlight), 1);
        buf.record_finished(&rollout(rid, vec![5, 6, 2], true, 1.0));
        assert_eq!(buf.ready_rids(), vec![rid]);
        let consumed = buf.consume(&[rid]);
        assert_eq!(consumed[0].partial, vec![5, 6, 2]);
        assert!(buf.all_consumed());
        buf.clear_consumed();
        assert!(buf.is_empty());
    }

    #[test]
    fn on_policy_termination_discards_partial() {
        let mut buf = RolloutBuffer::new();
        let rid = buf.load_prompt(0, 7, vec![1, 2], 64);
        buf.dispatch(&[rid]);
        buf.record_terminated(&rollout(rid, vec![5, 6], false, 1.0), Mode::OnPolicy);
        let e = buf.get(rid).unwrap();
        assert!(e.partial.is_empty());
        assert_eq!(e.lifecycle, Lifecycle::Scavenged);
        assert_eq!(e.resumes, 1);
        assert_eq!(e.born_version, None);
        // re-dispatch starts from scratch
        let reqs = buf.dispatch(&[rid]);
        assert!(reqs[0].resumed.is_empty());
    }

    #[test]
    fn partial_termination_keeps_tokens_and_logps() {
        let mut buf = RolloutBuffer::new();
        let rid = buf.load_prompt(0, 7, vec![1, 2], 64);
        buf.dispatch(&[rid]);
        buf.record_terminated(&rollout(rid, vec![5, 6], false, 1.0), Mode::Partial);
        let e = buf.get(rid).unwrap();
        assert_eq!(e.partial, vec![5, 6]);
        assert_eq!(e.partial_logp.len(), 2);
        assert_eq!(e.born_version, Some(3));
        let reqs = buf.dispatch(&[rid]);
        assert_eq!(reqs[0].resumed, vec![5, 6]);
        assert_eq!(reqs[0].resumed_logp, vec![-0.5, -0.5]);
        assert_eq!(reqs[0].resumes, 1);
    }

    #[test]
    fn ready_order_is_completion_order() {
        let mut buf = RolloutBuffer::new();
        let a = buf.load_prompt(0, 1, vec![1], 64);
        let b = buf.load_prompt(1, 2, vec![1], 64);
        let c = buf.load_prompt(2, 3, vec![1], 64);
        buf.dispatch(&[a, b, c]);
        buf.record_finished(&rollout(b, vec![2], true, 0.5));
        buf.record_finished(&rollout(c, vec![2], true, 1.5));
        buf.record_finished(&rollout(a, vec![2], true, 1.0));
        assert_eq!(buf.ready_rids(), vec![b, a, c]);
    }

    #[test]
    fn all_consumed_gates_group_barrier() {
        let mut buf = RolloutBuffer::new();
        let a = buf.load_prompt(0, 1, vec![1], 64);
        let b = buf.load_prompt(1, 2, vec![1], 64);
        buf.dispatch(&[a]);
        buf.record_finished(&rollout(a, vec![2], true, 1.0));
        buf.consume(&[a]);
        assert!(!buf.all_consumed(), "b is still fresh");
        buf.dispatch(&[b]);
        buf.record_finished(&rollout(b, vec![2], true, 2.0));
        buf.consume(&[b]);
        assert!(buf.all_consumed());
    }

    #[test]
    fn dispatch_stamped_records_version() {
        let mut buf = RolloutBuffer::new();
        let rid = buf.load_prompt(0, 7, vec![1, 2], 64);
        assert_eq!(buf.get(rid).unwrap().dispatch_version, None);
        buf.dispatch_stamped(&[rid], 9);
        assert_eq!(buf.get(rid).unwrap().dispatch_version, Some(9));
        // fall back to the stamp when no token was ever sampled: a rollout
        // with born_version None leaves the dispatch stamp as the birth
        let mut r = rollout(rid, vec![], false, 1.0);
        r.request.born_version = None;
        buf.record_terminated(&r, Mode::Partial);
        assert_eq!(buf.staleness_at(rid, 11), Some(2));
    }

    #[test]
    fn bounded_consume_within_cap_is_plain_consume() {
        let mut buf = RolloutBuffer::new();
        let rid = buf.load_prompt(0, 7, vec![1, 2], 64);
        buf.dispatch_stamped(&[rid], 3);
        buf.record_finished(&rollout(rid, vec![5, 6], true, 1.0));
        // born at 3, update enters at 5 -> staleness 2, cap 2: consumed
        let out = buf.consume_bounded(&[rid], 5, Some(2));
        assert_eq!(out.entries.len(), 1);
        assert!(out.resynced.is_empty() && out.dropped.is_empty());
        assert!(buf.all_consumed());
    }

    #[test]
    fn bounded_consume_resyncs_first_violation() {
        let mut buf = RolloutBuffer::new();
        let rid = buf.load_prompt(0, 7, vec![1, 2], 64);
        buf.dispatch_stamped(&[rid], 3);
        buf.record_finished(&rollout(rid, vec![5, 6], true, 1.0));
        // born at 3, update enters at 6 -> staleness 3 > cap 2: re-sync
        let out = buf.consume_bounded(&[rid], 6, Some(2));
        assert!(out.entries.is_empty() && out.dropped.is_empty());
        assert_eq!(out.resynced, vec![rid]);
        let e = buf.get(rid).unwrap();
        assert_eq!(e.lifecycle, Lifecycle::Scavenged);
        assert!(e.partial.is_empty(), "re-sync discards the stale tokens");
        assert_eq!(e.born_version, None);
        assert_eq!(e.stale_resyncs, 1);
        assert_eq!(buf.schedulable(), vec![rid], "re-synced entry regenerates");
    }

    #[test]
    fn bounded_consume_drops_second_violation() {
        let mut buf = RolloutBuffer::new();
        let rid = buf.load_prompt(0, 7, vec![1, 2], 64);
        buf.dispatch_stamped(&[rid], 3);
        buf.record_finished(&rollout(rid, vec![5, 6], true, 1.0));
        buf.consume_bounded(&[rid], 6, Some(2)); // first violation: re-sync
        buf.dispatch_stamped(&[rid], 6);
        let mut r = rollout(rid, vec![7], true, 2.0);
        r.request.born_version = Some(6);
        r.finish_version = 6;
        buf.record_finished(&r);
        // stale again (entered at 9, born 6, cap 2): dropped untrained
        let out = buf.consume_bounded(&[rid], 9, Some(2));
        assert!(out.entries.is_empty() && out.resynced.is_empty());
        assert_eq!(out.dropped, vec![rid]);
        assert!(buf.all_consumed(), "dropped entries still clear the barrier");
    }

    #[test]
    fn bounded_consume_no_cap_matches_consume() {
        let mut buf = RolloutBuffer::new();
        let rid = buf.load_prompt(0, 7, vec![1, 2], 64);
        buf.dispatch_stamped(&[rid], 0);
        buf.record_finished(&rollout(rid, vec![5], true, 1.0));
        // arbitrarily stale (born 3, entered 1000) but cap None: trained
        let out = buf.consume_bounded(&[rid], 1_000, None);
        assert_eq!(out.entries.len(), 1);
        assert_eq!(out.entries[0].partial, vec![5]);
        assert!(buf.all_consumed());
    }

    #[test]
    #[should_panic(expected = "consume non-ready")]
    fn consume_requires_ready() {
        let mut buf = RolloutBuffer::new();
        let a = buf.load_prompt(0, 1, vec![1], 64);
        buf.consume(&[a]);
    }

    #[test]
    fn invariants_catch_mismatched_logps() {
        let mut buf = RolloutBuffer::new();
        let a = buf.load_prompt(0, 1, vec![1], 4);
        buf.dispatch(&[a]);
        let mut r = rollout(a, vec![2, 3], false, 1.0);
        r.logp = vec![-0.1];
        buf.record_terminated(&r, Mode::Partial);
        assert!(buf.check_invariants().is_err());
    }
}
