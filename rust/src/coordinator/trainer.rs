//! Trainer: turns buffer entries into fixed-shape train_step calls.
//!
//! Selective batching lives here: the controller decides *which* ready
//! trajectories form an update batch and in what order; this module
//! computes batch-coupled advantages (Reinforce++ z-score — the paper's
//! normalization effect), marshals [Bt, T] arrays and drives the AOT
//! train_step.  Update batches larger than the compiled Bt are split into
//! sequential micro-steps sharing the same advantage normalization.

use crate::coordinator::buffer::BufferEntry;
use crate::rl::advantage::{advantages, AdvantageKind, BaselineState, RewardEntry};
use crate::runtime::{ParamState, Runtime, TrainBatch, TrainStats};
use crate::tasks::{Reward, Task};
use crate::tokenizer::PAD;
use anyhow::{bail, Result};

/// Per-update telemetry (one row of the Fig.3/Fig.4 training curves).
#[derive(Debug, Clone, Default)]
pub struct UpdateLog {
    pub update_idx: usize,
    pub policy_version: u64,
    pub n_traj: usize,
    pub mean_reward: f64,
    pub accuracy: f64,
    pub format_rate: f64,
    pub mean_resp_len: f64,
    pub max_resp_len: usize,
    /// Mean policy-version staleness of the batch (off-policiness proxy).
    pub mean_staleness: f64,
    pub stats: TrainStats,
}

pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub adv_kind: AdvantageKind,
    pub lr: f32,
    baseline: BaselineState,
    update_count: usize,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, adv_kind: AdvantageKind, lr: f32) -> Self {
        Self { rt, adv_kind, lr, baseline: BaselineState::default(), update_count: 0 }
    }

    pub fn updates(&self) -> usize {
        self.update_count
    }

    /// Grade entries with the task verifier.
    pub fn grade(&self, task: &dyn Task, problems: &[crate::tasks::Problem],
                 entries: &[BufferEntry]) -> Vec<Reward> {
        entries
            .iter()
            .map(|e| task.verify(&problems[e.problem_idx], &e.partial))
            .collect()
    }

    /// One logical update over `entries` (>= 1 micro-steps of size Bt).
    /// Advantages are normalized over the WHOLE update batch, so batch
    /// composition — what the controller selected — shapes the gradient.
    pub fn update(&mut self, state: &mut ParamState, entries: &[BufferEntry],
                  rewards: &[Reward]) -> Result<UpdateLog> {
        if entries.is_empty() {
            bail!("empty update batch");
        }
        assert_eq!(entries.len(), rewards.len());
        let sh = self.rt.manifest.shapes.clone();
        let (bt, t) = (sh.train_batch, sh.train_seq);
        // Staleness is measured against the version ENTERING this logical
        // update (the canonical convention — see `crate::rl::staleness`).
        // Captured BEFORE the micro-step loop: each micro-step bumps
        // `state.version`, so measuring afterwards inflated every sample
        // by `micro_steps - 1`.
        let v_enter = state.version;

        let reward_entries: Vec<RewardEntry> = entries
            .iter()
            .zip(rewards)
            .map(|(e, r)| RewardEntry { reward: r.total(), group: e.prompt_id })
            .collect();
        let advs = advantages(self.adv_kind, &reward_entries, &mut self.baseline);

        let mut stats_acc = TrainStats::default();
        let mut micro_steps = 0usize;
        for chunk_start in (0..entries.len()).step_by(bt) {
            let chunk = &entries[chunk_start..(chunk_start + bt).min(entries.len())];
            let adv_chunk = &advs[chunk_start..chunk_start + chunk.len()];
            let mut tokens = vec![PAD; bt * t];
            let mut mask = vec![0f32; bt * t];
            let mut adv = vec![0f32; bt * t];
            let mut old_logp = vec![0f32; bt * t];
            for (b, (e, &a)) in chunk.iter().zip(adv_chunk).enumerate() {
                let plen = e.prompt.len().min(t);
                for (i, &tokv) in e.prompt.iter().take(plen).enumerate() {
                    tokens[b * t + i] = tokv;
                }
                let rlen = e.partial.len().min(t - plen);
                for i in 0..rlen {
                    let col = plen + i;
                    tokens[b * t + col] = e.partial[i];
                    mask[b * t + col] = 1.0;
                    adv[b * t + col] = a as f32;
                    old_logp[b * t + col] = e.partial_logp[i];
                }
            }
            let s = self.rt.train_step(state, &TrainBatch {
                tokens,
                mask,
                adv,
                old_logp,
                lr: self.lr,
            })?;
            stats_acc.loss += s.loss;
            stats_acc.mean_ratio += s.mean_ratio;
            stats_acc.clip_frac += s.clip_frac;
            stats_acc.mean_entropy += s.mean_entropy;
            stats_acc.approx_kl += s.approx_kl;
            stats_acc.grad_norm += s.grad_norm;
            micro_steps += 1;
        }
        let k = micro_steps as f32;
        stats_acc.loss /= k;
        stats_acc.mean_ratio /= k;
        stats_acc.clip_frac /= k;
        stats_acc.mean_entropy /= k;
        stats_acc.approx_kl /= k;
        stats_acc.grad_norm /= k;

        self.update_count += 1;
        Ok(assemble_update_log(self.update_count, state.version, v_enter,
                               entries, rewards, stats_acc))
    }
}

/// Off-policy staleness of one buffer entry against an update entering at
/// `v_enter`, through the canonical [`crate::rl::staleness`] helper.  The
/// birth version falls back through the dispatch stamp
/// ([`BufferEntry::dispatch_version`]) to the finish version, so every
/// entry reports an exact delta.
pub fn entry_staleness(e: &BufferEntry, v_enter: u64) -> u64 {
    let born = e.born_version.or(e.dispatch_version).unwrap_or(e.finish_version);
    crate::rl::staleness(v_enter, born)
}

/// Assemble the per-update telemetry row.  Pure and structurally guarded:
/// an empty batch yields zeroed means, never NaN — `Trainer::update`
/// rejects empty batches up front, but the JSON log emitters downstream
/// must stay poison-free even if a future caller slips one through.
pub fn assemble_update_log(update_idx: usize, policy_version: u64, v_enter: u64,
                           entries: &[BufferEntry], rewards: &[Reward],
                           stats: TrainStats) -> UpdateLog {
    let n = entries.len() as f64;
    let mean = |sum: f64| if entries.is_empty() { 0.0 } else { sum / n };
    UpdateLog {
        update_idx,
        policy_version,
        n_traj: entries.len(),
        mean_reward: mean(rewards.iter().map(|r| r.total()).sum()),
        accuracy: mean(rewards.iter().filter(|r| r.correct).count() as f64),
        format_rate: mean(rewards.iter().filter(|r| r.format_ok).count() as f64),
        mean_resp_len: mean(entries.iter().map(|e| e.partial.len() as f64).sum()),
        max_resp_len: entries.iter().map(|e| e.partial.len()).max().unwrap_or(0),
        mean_staleness: mean(entries.iter()
            .map(|e| entry_staleness(e, v_enter) as f64)
            .sum()),
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::buffer::Lifecycle;

    fn entry(born: Option<u64>, dispatch: Option<u64>, finish: u64,
             toks: usize) -> BufferEntry {
        BufferEntry {
            rid: 0,
            problem_idx: 0,
            prompt_id: 0,
            prompt: vec![1, 2],
            partial: vec![7; toks],
            partial_logp: vec![-0.5; toks],
            complete: true,
            lifecycle: Lifecycle::Ready,
            born_version: born,
            finish_version: finish,
            dispatch_version: dispatch,
            stale_resyncs: 0,
            resumes: 0,
            max_new: 64,
            finished_at: 1.0,
            clipped: false,
        }
    }

    /// The satellite-2 NaN guard: an empty batch must produce finite
    /// (zeroed) means, not 0/0 = NaN poisoning the JSON logs.
    #[test]
    fn empty_batch_log_is_finite() {
        let log = assemble_update_log(1, 5, 4, &[], &[], TrainStats::default());
        assert_eq!(log.n_traj, 0);
        for v in [log.mean_reward, log.accuracy, log.format_rate,
                  log.mean_resp_len, log.mean_staleness] {
            assert!(v.is_finite(), "empty-batch log emitted {v}");
            assert_eq!(v, 0.0);
        }
        assert_eq!(log.max_resp_len, 0);
    }

    /// Staleness is measured at update ENTRY (v_enter), not after the
    /// micro-step bumps, and falls back born -> dispatch -> finish.
    #[test]
    fn log_staleness_uses_entry_version_and_fallback_chain() {
        assert_eq!(entry_staleness(&entry(Some(3), Some(4), 5, 1), 6), 3);
        assert_eq!(entry_staleness(&entry(None, Some(4), 5, 1), 6), 2);
        assert_eq!(entry_staleness(&entry(None, None, 5, 1), 6), 1);
        let entries = [entry(Some(3), None, 3, 2), entry(Some(5), None, 5, 4)];
        let rewards = [Reward::graded(true), Reward::bad_format()];
        // v_enter 5: staleness 2 and 0 -> mean 1.0 (the old inline formula
        // measured post-bump and was off by micro_steps - 1)
        let log = assemble_update_log(2, 7, 5, &entries, &rewards,
                                      TrainStats::default());
        assert_eq!(log.mean_staleness, 1.0);
        assert_eq!(log.accuracy, 0.5);
        assert_eq!(log.mean_resp_len, 3.0);
        assert_eq!(log.max_resp_len, 4);
    }
}

/// Supervised warm start over problem (prompt ++ sft_target) pairs.
/// Stands in for the paper's pretrained instruct starting checkpoints.
pub fn sft_warm_start(rt: &Runtime, state: &mut ParamState,
                      problems: &[&crate::tasks::Problem], steps: usize, lr: f32,
                      log_every: usize) -> Result<Vec<f32>> {
    let sh = rt.manifest.shapes.clone();
    let (bt, t) = (sh.train_batch, sh.train_seq);
    let mut losses = Vec::new();
    let mut idx = 0usize;
    for step in 0..steps {
        let mut tokens = vec![PAD; bt * t];
        let mut weights = vec![0f32; bt * t];
        for b in 0..bt {
            let p = problems[idx % problems.len()];
            idx += 1;
            let plen = p.prompt.len().min(t);
            for (i, &tok) in p.prompt.iter().take(plen).enumerate() {
                tokens[b * t + i] = tok;
            }
            let rlen = p.sft_target.len().min(t - plen);
            for i in 0..rlen {
                tokens[b * t + plen + i] = p.sft_target[i];
                weights[b * t + plen + i] = 1.0;
            }
        }
        let (loss, _gnorm) = rt.sft_step(state, &tokens, &weights, lr)?;
        if log_every > 0 && step % log_every == 0 {
            eprintln!("  sft step {step}: loss {loss:.4}");
        }
        losses.push(loss);
    }
    Ok(losses)
}
