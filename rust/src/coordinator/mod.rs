//! The paper's system contribution: length-aware controller, stateful
//! rollout buffer, scheduler variants and the trainer glue.

pub mod buffer;
pub mod controller;
pub mod pipeline;
pub mod trainer;

pub use buffer::{BoundedConsume, BufferEntry, Lifecycle, Mode, RolloutBuffer};
pub use controller::{Controller, EvalResult, LogRow, LoopConfig, RunResult, SchedulerKind};
pub use pipeline::Pipeline;
pub use trainer::{sft_warm_start, Trainer, UpdateLog};
