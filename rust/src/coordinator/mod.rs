//! The paper's system contribution: length-aware controller, stateful
//! rollout buffer, scheduler variants and the trainer glue.

pub mod buffer;
pub mod controller;
pub mod trainer;

pub use buffer::{BufferEntry, Lifecycle, Mode, RolloutBuffer};
pub use controller::{Controller, EvalResult, LogRow, LoopConfig, RunResult, SchedulerKind};
pub use trainer::{sft_warm_start, Trainer, UpdateLog};
