//! The SortedRL length-aware controller (paper §3), driven by the unified
//! `SchedulePolicy` decision API.
//!
//! The controller owns the RL loop's *state* — dataloader, rollout buffer,
//! engine pool, trainer — and exposes it to the generic policy driver
//! (`sched::policy::drive`) through [`LiveBackend`], the live
//! `ScheduleBackend`.  All scheduling *decisions* (when to load prompts,
//! admit, early-terminate, clip, train) live in `sched::policy` and are
//! shared verbatim with the simulator backend, so a policy behaves
//! identically at paper scale in the simulator and in a real training run.
//!
//! Scheduler variants cover every strategy the paper evaluates plus one:
//!   * `SortedOnPolicy` / `SortedPartial` — SortedRL's two modes (§3.2)
//!   * `Baseline`   — large rollout batch, sync barrier, k sequential
//!     off-policy updates (the canonical VeRL-style pipeline)
//!   * `PostHocSort` — ablation: baseline + sort by length before updating
//!   * `NoGroupedRollout` — ablation: oversubscription without the group
//!     barrier (biases training to short responses; Fig. 6a)
//!   * `AsyncUpdate` — trainer updates overlap continued decoding (no
//!     harvest barrier; bounded staleness via periodic partial re-sync)

use crate::coordinator::buffer::{BufferEntry, Lifecycle, Mode, RolloutBuffer};
use crate::coordinator::pipeline::Pipeline;
use crate::coordinator::trainer::{entry_staleness, Trainer, UpdateLog};
use crate::data::{DataLoader, Dataset};
use crate::metrics::{bubble_fraction, PhaseClock};
use crate::rl::advantage::AdvantageKind;
use crate::rollout::kv::{KvConfig, KvMode, DEFAULT_KV_PAGE};
use crate::rollout::{EngineConfig, Rollout};
use crate::runtime::{ParamState, Runtime};
use crate::sched::policy::{
    drive_traced, EngineLoad, EngineSpec, HarvestAction, HarvestItem, LaneView, PolicyBuilder,
    PolicyParams, SchedView, ScheduleBackend,
};
use crate::sched::{DispatchPolicy, EnginePool, PoolConfig, PredictorKind, TailConfig};
use crate::tasks::{Reward, Task};
use crate::trace::{SloSummary, Tracer};
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::PathBuf;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    SortedOnPolicy,
    SortedPartial,
    Baseline,
    PostHocSort,
    NoGroupedRollout,
    AsyncUpdate,
}

impl SchedulerKind {
    pub const ALL: [SchedulerKind; 6] = [
        SchedulerKind::SortedOnPolicy,
        SchedulerKind::SortedPartial,
        SchedulerKind::Baseline,
        SchedulerKind::PostHocSort,
        SchedulerKind::NoGroupedRollout,
        SchedulerKind::AsyncUpdate,
    ];

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "sorted-on-policy" | "on-policy" => Self::SortedOnPolicy,
            "sorted-partial" | "partial" => Self::SortedPartial,
            "baseline" => Self::Baseline,
            "post-hoc-sort" => Self::PostHocSort,
            "no-grouped" => Self::NoGroupedRollout,
            "async" | "async-update" => Self::AsyncUpdate,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::SortedOnPolicy => "sorted-on-policy",
            Self::SortedPartial => "sorted-partial",
            Self::Baseline => "baseline",
            Self::PostHocSort => "post-hoc-sort",
            Self::NoGroupedRollout => "no-grouped",
            Self::AsyncUpdate => "async",
        }
    }

    /// Canonical names, '|'-joined — what a failed parse should suggest.
    pub fn valid_names() -> String {
        let names: Vec<&'static str> = Self::ALL.iter().map(|k| k.name()).collect();
        names.join("|")
    }

    /// True for kinds whose interrupted generations keep their progress
    /// (enables APRIL-style straggler preemption in the engine pool).
    pub fn resumes_partials(&self) -> bool {
        matches!(self, Self::SortedPartial | Self::AsyncUpdate)
    }
}

#[derive(Debug, Clone)]
pub struct LoopConfig {
    pub scheduler: SchedulerKind,
    /// b: prompts per rollout batch.
    pub rollout_prompts: usize,
    /// n: prompt batches per group (sorted modes; pool = n*b prompts).
    pub group_size: usize,
    /// G: responses sampled per prompt.
    pub samples_per_prompt: usize,
    /// Trajectories per logical update (advantage-normalization scope).
    pub update_batch: usize,
    pub max_updates: usize,
    pub lr: f32,
    pub temperature: f32,
    pub seed: u64,
    pub adv: AdvantageKind,
    /// Cap on generated tokens per response.
    pub max_new: usize,
    /// Evaluate every k updates (0 = never).
    pub eval_every: usize,
    /// Evaluate on at most this many held-out problems.
    pub eval_limit: usize,
    pub verbose: bool,
    /// Engines in the rollout pool (each with its own lanes + KV cache).
    pub num_engines: usize,
    /// Length predictor driving admission order / straggler detection.
    pub predictor: PredictorKind,
    /// How the pool places queued requests onto engines.
    pub dispatch: DispatchPolicy,
    /// Cross-engine work stealing: wrap the scheduler in the
    /// `WorkStealing` policy composer (idle engines pull local backlog or
    /// whole lanes from loaded peers, KV budget permitting).
    pub steal: bool,
    /// Per-engine KV budget in tokens; `usize::MAX` disables the model.
    /// Reserve mode charges prompt + generation cap per admitted lane;
    /// paged mode charges the actual context in `kv_page` pages.
    pub kv_budget: usize,
    /// Reserve-the-cap vs paged KV accounting (`--kv-mode`).
    pub kv_mode: KvMode,
    /// Page granularity for paged accounting in tokens (`--kv-page`).
    pub kv_page: usize,
    /// Write a Chrome-trace-event JSON (Perfetto-loadable) of the run here.
    pub trace_out: Option<PathBuf>,
    /// End-to-end latency SLO in *milliseconds* (host wall clock); enables
    /// per-request span recording and the goodput column in `RunResult::slo`.
    pub slo_ms: Option<f64>,
    /// Off-policy-degree hard cap (`--staleness N`): no sample older than
    /// N trainer updates is ever consumed for training — over-stale
    /// samples are re-synced (regenerated under current weights) once and
    /// dropped on a repeat violation.  For the async scheduler N also
    /// becomes the periodic re-sync window (`ASYNC_SYNC_EVERY` is only
    /// the derived default when unset).  `None` = legacy behavior: no
    /// consume-time cap, default sync window.
    pub staleness: Option<usize>,
    /// Tail-round packing (`--tail-threshold`/`--tail-engines`): defer
    /// requests whose predicted length exceeds the threshold into batched
    /// tail rounds on a dedicated engine group, elastically borrowing
    /// lanes + KV from the head group at round boundaries.  `None`
    /// disables the wrapper entirely.
    pub tail: Option<TailConfig>,
    /// Heterogeneous fleet (`--engine-spec`): one spec per engine (lane
    /// window, KV budget, routing speed).  Empty = uniform fleet.  When
    /// non-empty its length must equal `num_engines` (the CLI derives
    /// `num_engines` from the spec string).
    pub engine_specs: Vec<EngineSpec>,
}

impl Default for LoopConfig {
    fn default() -> Self {
        Self {
            scheduler: SchedulerKind::SortedOnPolicy,
            rollout_prompts: 8,
            group_size: 4,
            samples_per_prompt: 2,
            update_batch: 16,
            max_updates: 50,
            lr: 1e-3,
            temperature: 1.0,
            seed: 0,
            adv: AdvantageKind::ReinforcePlusPlus,
            max_new: 160,
            eval_every: 10,
            eval_limit: 64,
            verbose: false,
            num_engines: 1,
            predictor: PredictorKind::History,
            dispatch: DispatchPolicy::LeastLoaded,
            steal: false,
            kv_budget: usize::MAX,
            kv_mode: KvMode::Reserve,
            kv_page: DEFAULT_KV_PAGE,
            trace_out: None,
            slo_ms: None,
            staleness: None,
            tail: None,
            engine_specs: Vec::new(),
        }
    }
}

/// One row of the training telemetry (drives Figs. 3/4/6/9).
#[derive(Debug, Clone)]
pub struct LogRow {
    pub update: UpdateLog,
    pub epochs: f64,
    pub rollout_tokens: u64,
    pub rollout_secs: f64,
    pub eval: Option<EvalResult>,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct EvalResult {
    /// Mean total reward normalized by Reward::MAX (the "validation score").
    pub score: f64,
    pub accuracy: f64,
    pub format_rate: f64,
    pub mean_resp_len: f64,
}

/// Aggregated outcome of a training run.
pub struct RunResult {
    pub rows: Vec<LogRow>,
    pub final_eval: EvalResult,
    pub phase_clock: PhaseClock,
    /// (bubble_ratio, mean_occupancy) aggregated over rollout phases.
    pub bubble_ratio: f64,
    pub total_rollout_tokens: u64,
    /// Trajectories discarded without training (no-grouped ablation).
    pub discarded: u64,
    /// TTFT/TPOT/e2e quantiles + goodput, present iff tracing was enabled
    /// (`LoopConfig::trace_out` or `LoopConfig::slo_ms`).
    pub slo: Option<SloSummary>,
    /// Per-sample off-policy staleness of every TRAINED sample, measured
    /// at consume time against the version entering its update
    /// (staleness value -> count).  Exact, not inferred: versions are
    /// stamped on lanes at dispatch and samples at harvest.
    pub staleness_hist: BTreeMap<u64, u64>,
    /// Max key of `staleness_hist` (0 for an empty run) — with
    /// `--staleness N` this is provably <= N.
    pub max_staleness: u64,
    /// Samples bounced by the `--staleness` cap and regenerated under
    /// fresh weights (cap-dropped samples count into `discarded`).
    pub stale_resyncs: u64,
    /// Batched tail rounds opened on the tail engine group (0 without
    /// `--tail-threshold`).
    pub tail_rounds: u64,
    /// Deferred-long requests admitted through tail rounds.
    pub tail_admitted: u64,
    /// Applied elastic lane/KV repartitions at tail-round boundaries.
    pub repartitions: u64,
    /// Bubble ratio of the head engine group alone (== `bubble_ratio`'s
    /// whole-pool aggregation restricted to head engines; the whole pool
    /// when no tail group is configured).
    pub head_bubble: f64,
    /// Bubble ratio of the tail engine group (0.0 when no tail group).
    pub tail_bubble: f64,
}

pub struct Controller<'rt> {
    rt: &'rt Runtime,
    task: Box<dyn Task>,
    dataset: Dataset,
    loader: DataLoader,
    cfg: LoopConfig,
    buffer: RolloutBuffer,
    // rollout-phase occupancy aggregation (paper Eq. 4 numerator/denominator:
    // idle capacity-time and TOTAL capacity-time, both in lane-seconds)
    idle_area: f64,
    capacity_area: f64,
    rollout_tokens: u64,
    discarded: u64,
    // tail-round bookkeeping (LiveBackend mirrors SimBackend's counting
    // convention: a targeted admit landing on a tail-group engine opens a
    // round; the round closes when the tail group drains idle)
    tail_rounds: u64,
    tail_admitted: u64,
    tail_round_open: bool,
    repartitions: u64,
}

impl<'rt> Controller<'rt> {
    pub fn new(rt: &'rt Runtime, task: Box<dyn Task>, dataset: Dataset,
               cfg: LoopConfig) -> Self {
        let loader = DataLoader::new(dataset.train.len(), cfg.seed ^ 0x11);
        Controller {
            rt,
            task,
            dataset,
            loader,
            cfg,
            buffer: RolloutBuffer::new(),
            idle_area: 0.0,
            capacity_area: 0.0,
            rollout_tokens: 0,
            discarded: 0,
            tail_rounds: 0,
            tail_admitted: 0,
            tail_round_open: false,
            repartitions: 0,
        }
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    fn engine_cfg(&self, greedy: bool) -> EngineConfig {
        EngineConfig {
            temperature: self.cfg.temperature,
            greedy,
            seed: self.cfg.seed,
            kv: KvConfig {
                mode: self.cfg.kv_mode,
                budget: self.cfg.kv_budget,
                page: self.cfg.kv_page,
            },
        }
    }

    /// Build the rollout engine pool. `preempt` enables APRIL-style
    /// straggler requeue (partial-resuming modes only — on-policy semantics
    /// would discard the preempted tokens anyway).
    fn make_pool(&self, greedy: bool, preempt: bool) -> EnginePool<'rt> {
        let mut pool = EnginePool::new(self.rt, self.engine_cfg(greedy), PoolConfig {
            num_engines: self.cfg.num_engines.max(1),
            dispatch: self.cfg.dispatch,
            predictor: self.cfg.predictor,
            preempt,
            ..PoolConfig::default()
        });
        if !self.cfg.engine_specs.is_empty() {
            pool.apply_specs(&self.cfg.engine_specs);
        }
        pool
    }

    fn effective_max_new(&self) -> usize {
        // keep prompt + response inside the training unroll T
        let t = self.rt.manifest.shapes.train_seq;
        let max_prompt = self
            .dataset
            .train
            .iter()
            .map(|p| p.prompt.len())
            .max()
            .unwrap_or(0);
        self.cfg.max_new.min(t.saturating_sub(max_prompt + 1))
    }

    /// Load `n_prompts` prompts (G samples each); returns entries created.
    fn load_prompts(&mut self, n_prompts: usize) -> usize {
        let max_new = self.effective_max_new();
        let mut count = 0;
        for idx in self.loader.next_batch(n_prompts) {
            let p = &self.dataset.train[idx];
            for _ in 0..self.cfg.samples_per_prompt {
                self.buffer.load_prompt(idx, p.id, p.prompt.clone(), max_new);
                count += 1;
            }
        }
        count
    }

    fn absorb_engine_occupancy(&mut self, pool: &EnginePool) {
        let (idle, capacity, tokens) = pool.occupancy();
        self.idle_area += idle;
        self.capacity_area += capacity;
        self.rollout_tokens += tokens;
        if self.cfg.verbose && pool.score.count() > 0 {
            eprintln!(
                "[pool] predictor {}: {} scored, MAE {:.1} tok, tau {:.3}; \
                 {} preempted, {} stolen, {} throttled, {} kv-shed",
                self.cfg.predictor.name(),
                pool.score.count(),
                pool.score.mae(),
                pool.score.kendall_tau(),
                pool.preempted(),
                pool.stolen(),
                pool.throttled(),
                pool.kv_sheds()
            );
        }
    }

    /// Aggregate bubble ratio over every rollout phase so far: idle
    /// capacity-time / total capacity-time (paper Eq. 4).  The paper's
    /// denominator is total pipeline time; ours is the rollout phase only,
    /// because the engine clock is virtual (it advances only inside engine
    /// calls), so trainer/eval host time can never masquerade as engine
    /// idleness.  See `metrics::bubble_fraction` for the pinned definition.
    pub fn bubble_ratio(&self) -> f64 {
        bubble_fraction(self.idle_area, self.capacity_area)
    }

    // ------------------------------------------------------------------
    // evaluation (greedy)
    // ------------------------------------------------------------------

    pub fn evaluate(&self, state: &ParamState) -> Result<EvalResult> {
        let max_new = self.effective_max_new();
        let problems: Vec<(usize, &crate::tasks::Problem)> = self
            .dataset
            .eval
            .iter()
            .take(self.cfg.eval_limit)
            .enumerate()
            .collect();
        if problems.is_empty() {
            return Ok(EvalResult::default());
        }
        let mut engine = self.make_pool(true, false);
        engine.submit(problems.iter().map(|(i, p)| {
            crate::rollout::Request::fresh(*i as u64, *i, p.id, p.prompt.clone(), max_new)
        }));
        let rollouts = engine.run_to_completion(state)?;
        let mut score = 0.0;
        let mut acc = 0.0;
        let mut fmt = 0.0;
        let mut len = 0.0;
        for r in &rollouts {
            let p = problems[r.request.problem_idx].1;
            let reward = self.task.verify(p, &r.response);
            score += reward.total() / Reward::MAX;
            acc += reward.correct as u8 as f64;
            fmt += reward.format_ok as u8 as f64;
            len += r.response.len() as f64;
        }
        let n = rollouts.len() as f64;
        Ok(EvalResult {
            score: score / n,
            accuracy: acc / n,
            format_rate: fmt / n,
            mean_resp_len: len / n,
        })
    }

    // ------------------------------------------------------------------
    // main loop — policy driver
    // ------------------------------------------------------------------

    /// Run the configured scheduler through the unified policy driver.
    /// The decision sequence comes from `sched::policy`; this method only
    /// wires the live backend together and aggregates the outcome.
    ///
    /// The async scheduler gets a true second thread: the trainer runs on
    /// a scoped worker connected by a bounded channel ([`Pipeline`]),
    /// owning the MASTER weights, while this thread keeps stepping the
    /// engine pool on a SERVING snapshot that lags by at most one update.
    /// Every other scheduler keeps the serial generate-then-train loop
    /// (their semantics have a harvest barrier anyway, so a second thread
    /// would only ever idle).
    pub fn run(&mut self, state: &mut ParamState) -> Result<RunResult> {
        let train_secs_at_start = self.rt.stats_snapshot().train_secs;
        let params = PolicyParams {
            refill_prompts: (self.cfg.group_size * self.cfg.rollout_prompts).max(1),
            entries_per_prompt: self.cfg.samples_per_prompt.max(1),
            update_batch: self.cfg.update_batch.max(1),
        };
        let mut policy = PolicyBuilder::new(self.cfg.scheduler, params)
            .steal(self.cfg.steal)
            .kv(KvConfig {
                mode: self.cfg.kv_mode,
                budget: self.cfg.kv_budget,
                page: self.cfg.kv_page,
            })
            .staleness(self.cfg.staleness)
            .tail(self.cfg.tail)
            .build();
        let preempt = self.cfg.scheduler.resumes_partials();
        let pool = self.make_pool(false, preempt);
        let max_updates = self.cfg.max_updates;
        let trace_out = self.cfg.trace_out.clone();
        let slo_secs = self.cfg.slo_ms.map(|ms| ms / 1000.0);
        let verbose = self.cfg.verbose;
        let mut tracer = if trace_out.is_some() || slo_secs.is_some() {
            Tracer::new(slo_secs, trace_out.is_some())
        } else {
            Tracer::disabled()
        };
        let cap = self.cfg.staleness.map(|n| n as u64);
        let threaded = self.cfg.scheduler == SchedulerKind::AsyncUpdate;
        let rt = self.rt;
        let (adv, lr) = (self.cfg.adv, self.cfg.lr);

        type DriveOut<'rt> = (EnginePool<'rt>, Vec<LogRow>, BTreeMap<u64, u64>, u64);
        // explicit reborrow: the drive borrows the serving state only for
        // the branch below, leaving `state` free for the final eval
        let serving = &mut *state;
        let (pool, rows, staleness_hist, stale_resyncs) = if threaded {
            std::thread::scope(|scope| -> Result<DriveOut<'rt>> {
                // the worker owns the trainer + master weights; each
                // completed update ships a serving snapshot back
                let mut trainer = Trainer::new(rt, adv, lr);
                let mut master = serving.clone();
                let pipeline = Pipeline::spawn(scope, move |(entries, rewards): TrainJob| {
                    trainer
                        .update(&mut master, &entries, &rewards)
                        .map(|log| (master.clone(), log))
                });
                let mut backend = LiveBackend {
                    ctl: &mut *self,
                    state: serving,
                    pool,
                    trainer: None,
                    pipeline: Some(pipeline),
                    staleness_cap: cap,
                    issued: 0,
                    last_staleness: BTreeMap::new(),
                    staleness_hist: BTreeMap::new(),
                    stale_resyncs: 0,
                    rows: Vec::new(),
                    stash: BTreeMap::new(),
                    max_updates,
                };
                let driven = drive_traced(policy.as_mut(), &mut backend, &mut tracer);
                // drain the worker even on a driver error — the final
                // in-flight update must install before the scope ends
                let flushed = backend.flush();
                driven?;
                flushed?;
                let LiveBackend {
                    pool, rows, staleness_hist, stale_resyncs, pipeline, ..
                } = backend;
                if let Some(p) = pipeline {
                    p.shutdown(); // empty — flush() drained it — but joins
                }
                Ok((pool, rows, staleness_hist, stale_resyncs))
            })?
        } else {
            let mut backend = LiveBackend {
                ctl: &mut *self,
                state: serving,
                pool,
                trainer: Some(Trainer::new(rt, adv, lr)),
                pipeline: None,
                staleness_cap: cap,
                issued: 0,
                last_staleness: BTreeMap::new(),
                staleness_hist: BTreeMap::new(),
                stale_resyncs: 0,
                rows: Vec::new(),
                stash: BTreeMap::new(),
                max_updates,
            };
            drive_traced(policy.as_mut(), &mut backend, &mut tracer)?;
            let LiveBackend { pool, rows, staleness_hist, stale_resyncs, .. } = backend;
            (pool, rows, staleness_hist, stale_resyncs)
        };

        let slo = if tracer.enabled() {
            let summary = tracer.slo_summary();
            if verbose {
                eprintln!(
                    "slo: ttft p50 {:.3}s p99 {:.3}s | tpot p50 {:.4}s | e2e p99 {:.3}s | goodput {:.3}",
                    summary.ttft_p50, summary.ttft_p99, summary.tpot_p50,
                    summary.e2e_p99, summary.goodput
                );
            }
            if let Some(path) = &trace_out {
                tracer.write_chrome(path)?;
                eprintln!("wrote {} trace events to {}", tracer.chrome_events(),
                          path.display());
            }
            Some(summary)
        } else {
            None
        };

        let tail_group = self.cfg.tail.map_or(0, |tc| tc.tail_engines);
        let (head_bubble, tail_bubble) = pool.bubble_split(tail_group);
        self.absorb_engine_occupancy(&pool);
        let phase_clock = PhaseClock {
            rollout: pool.host_secs(),
            inference: 0.0,
            update: self.rt.stats_snapshot().train_secs - train_secs_at_start,
        };
        let final_eval = self.evaluate(state)?;
        let max_staleness = staleness_hist.keys().next_back().copied().unwrap_or(0);
        Ok(RunResult {
            rows,
            final_eval,
            phase_clock,
            bubble_ratio: self.bubble_ratio(),
            total_rollout_tokens: self.rollout_tokens,
            discarded: self.discarded,
            slo,
            staleness_hist,
            max_staleness,
            stale_resyncs,
            tail_rounds: self.tail_rounds,
            tail_admitted: self.tail_admitted,
            repartitions: self.repartitions,
            head_bubble,
            tail_bubble,
        })
    }

    fn log_update(&mut self, rows: &mut Vec<LogRow>, state: &ParamState,
                  log: UpdateLog, engine_secs: f64, rollout_tokens: u64)
                  -> Result<()> {
        let eval = if self.cfg.eval_every > 0 && log.update_idx % self.cfg.eval_every == 0 {
            Some(self.evaluate(state)?)
        } else {
            None
        };
        if self.cfg.verbose {
            let ev = eval
                .map(|e| format!(" | eval score {:.3} acc {:.3} len {:.1}",
                                 e.score, e.accuracy, e.mean_resp_len))
                .unwrap_or_default();
            eprintln!(
                "upd {:>4} v{:<4} reward {:+.3} acc {:.2} fmt {:.2} len {:>5.1} stale {:.2} kl {:+.4}{}",
                log.update_idx, log.policy_version, log.mean_reward, log.accuracy,
                log.format_rate, log.mean_resp_len, log.mean_staleness,
                log.stats.approx_kl, ev
            );
        }
        rows.push(LogRow {
            update: log,
            epochs: self.loader.epochs_elapsed(),
            rollout_tokens,
            rollout_secs: engine_secs,
            eval,
        });
        Ok(())
    }
}

/// A trainer-thread job: the consumed (cap-cleared) entries + their
/// rewards, graded on the main thread so the worker only runs train_step.
type TrainJob = (Vec<BufferEntry>, Vec<Reward>);
/// What comes back: the post-update master weights snapshot (installed as
/// the serving state at the next hand-off point) and the update's log row.
type TrainOut = Result<(ParamState, UpdateLog)>;

/// The live `ScheduleBackend`: `EnginePool` + `RolloutBuffer` + `Trainer`
/// + `Runtime`, exposed to the generic policy driver.  The simulator mirror
/// is `sim::SimBackend`; both execute the same decision vocabulary.
///
/// Two training modes share this backend: serial (`trainer: Some`, every
/// `train` call blocks through train_step) and pipelined (`pipeline:
/// Some`, `train` hands the batch to the worker thread and returns so the
/// pool keeps decoding; the result installs at the NEXT `train` call — at
/// most one update in flight).
struct LiveBackend<'a, 'scope, 'rt> {
    ctl: &'a mut Controller<'rt>,
    /// SERVING weights: what the engine pool decodes with.  In pipelined
    /// mode this lags the worker's master copy by at most one update.
    state: &'a mut ParamState,
    pool: EnginePool<'rt>,
    /// Serial path only; `None` when the trainer moved into the worker.
    trainer: Option<Trainer<'rt>>,
    /// Pipelined path only: the bounded-channel hand-off to the worker.
    pipeline: Option<Pipeline<'scope, TrainJob, TrainOut>>,
    /// `--staleness` consume-time cap (None = unbounded).
    staleness_cap: Option<u64>,
    /// Logical updates ISSUED (== installed + in-flight).  The policy's
    /// update budget counts issues so the final in-flight update is never
    /// double-scheduled during drain.
    issued: usize,
    /// rid -> staleness of the most recent `train` call's consumed
    /// samples (the `staleness_of` tap the tracer reads).
    last_staleness: BTreeMap<u64, u64>,
    /// staleness value -> trained-sample count, whole run.
    staleness_hist: BTreeMap<u64, u64>,
    /// Samples bounced once by the cap and regenerated.
    stale_resyncs: u64,
    rows: Vec<LogRow>,
    /// Partial rollouts from the current harvest, keyed by rid, so
    /// `resolve` can route tokens + log-probs into the buffer.
    stash: BTreeMap<u64, Rollout>,
    max_updates: usize,
}

impl LiveBackend<'_, '_, '_> {
    fn record_update_log(&mut self, log: UpdateLog) -> Result<()> {
        let secs = self.pool.host_secs();
        // cumulative pool tokens NOW, not the end-of-run absorbed total —
        // rows must grow monotonically for the sample-efficiency curves
        let tokens = self.ctl.rollout_tokens + self.pool.tokens_out();
        let mut rows = std::mem::take(&mut self.rows);
        self.ctl.log_update(&mut rows, self.state, log, secs, tokens)?;
        self.rows = rows;
        Ok(())
    }

    /// Install one completed worker update: its master snapshot becomes
    /// the serving weights, then the log row is emitted (periodic eval
    /// runs against the freshly installed version).
    fn install(&mut self, out: TrainOut) -> Result<()> {
        let (new_state, log) = out?;
        *self.state = new_state;
        self.record_update_log(log)
    }

    /// Drain and install every in-flight update (run end / error paths).
    fn flush(&mut self) -> Result<()> {
        while self.pipeline.as_ref().is_some_and(|p| p.in_flight() > 0) {
            let out = self.pipeline.as_mut().expect("checked above").wait();
            self.install(out)?;
        }
        Ok(())
    }

    /// Tail engine-group size (clamped so at least one head engine
    /// remains); 0 without `--tail-threshold`.
    fn tail_group(&self) -> usize {
        let n = self.pool.num_engines();
        self.ctl
            .cfg
            .tail
            .map_or(0, |tc| tc.tail_engines.min(n.saturating_sub(1)))
    }

    fn in_tail_group(&self, engine: usize) -> bool {
        let group = self.tail_group();
        group > 0 && engine >= self.pool.num_engines() - group
    }
}

impl ScheduleBackend for LiveBackend<'_, '_, '_> {
    fn view(&self) -> SchedView {
        let buffer = &self.ctl.buffer;
        SchedView {
            running: self.pool.running(),
            queued: self.pool.queued(),
            ready: buffer.count(Lifecycle::Ready),
            fresh: buffer.count(Lifecycle::Fresh),
            unconsumed: buffer.len() - buffer.count(Lifecycle::Consumed),
            lanes: self.pool.lane_count(),
            updates: self.issued,
        }
    }

    fn schedulable(&self) -> Vec<u64> {
        self.ctl.buffer.schedulable()
    }

    fn ready_rids(&self) -> Vec<u64> {
        self.ctl.buffer.ready_rids()
    }

    fn ready_len(&self, rid: u64) -> usize {
        self.ctl.buffer.get(rid).map(|e| e.partial.len()).unwrap_or(0)
    }

    fn load_prompts(&mut self, prompts: usize) -> Result<usize> {
        Ok(self.ctl.load_prompts(prompts))
    }

    fn admit(&mut self, rids: &[u64], engine: Option<usize>) -> Result<()> {
        // stamp every lane with the serving weights version at dispatch:
        // the version deltas behind the --staleness cap are exact
        let reqs = self.ctl.buffer.dispatch_stamped(rids, self.state.version);
        // a targeted admit landing on a tail-group engine opens (or
        // extends) a tail round — same convention as SimBackend
        if let Some(i) = engine {
            if self.in_tail_group(i) && !rids.is_empty() {
                self.ctl.tail_admitted += rids.len() as u64;
                if !self.ctl.tail_round_open {
                    self.ctl.tail_round_open = true;
                    self.ctl.tail_rounds += 1;
                }
            }
        }
        match engine {
            Some(i) => self.pool.submit_to(i, reqs),
            None => self.pool.submit(reqs),
        }
        Ok(())
    }

    fn engine_loads(&self) -> Vec<EngineLoad> {
        self.pool.engine_loads()
    }

    fn engine_lanes(&self, engine: usize) -> Vec<LaneView> {
        match self.pool.engines().get(engine) {
            Some(e) => e
                .lane_progress()
                .into_iter()
                .map(|p| LaneView { lane: p.lane, progress: p.total, reserve: p.reserve })
                .collect(),
            None => Vec::new(),
        }
    }

    fn trace_clock(&self) -> f64 {
        self.pool.host_secs()
    }

    fn lane_rids(&self, engine: usize) -> Vec<(usize, u64)> {
        match self.pool.engines().get(engine) {
            Some(e) => e.lane_progress().into_iter().map(|p| (p.lane, p.rid)).collect(),
            None => Vec::new(),
        }
    }

    fn steal(&mut self, from: usize, to: usize, lane: Option<usize>) -> Result<bool> {
        Ok(self.pool.steal_to(from, to, lane, self.state.version))
    }

    fn throttle(&mut self, engine: usize) -> Result<bool> {
        Ok(self.pool.throttle(engine, self.state.version))
    }

    fn repartition(&mut self, engine: usize, lanes: usize, kv: usize) -> Result<bool> {
        let applied = self.pool.repartition(engine, lanes, kv);
        if applied {
            self.ctl.repartitions += 1;
        }
        Ok(applied)
    }

    fn predicted_len(&self, rid: u64) -> Option<usize> {
        // only schedulable (not-yet-dispatched) entries classify for tail
        // deferral — in-flight and harvested work is already placed
        let e = self.ctl.buffer.get(rid)?;
        matches!(e.lifecycle, Lifecycle::Fresh | Lifecycle::Scavenged)
            .then(|| self.pool.predict_stamp(e.prompt_id, e.prompt.len()))
            .flatten()
    }

    fn step(&mut self) -> Result<usize> {
        self.pool.admit(self.state)?;
        if self.pool.running() > 0 {
            self.pool.step(self.state)?;
        }
        let rollouts = self.pool.drain_finished();
        for r in &rollouts {
            self.ctl.buffer.record_finished(r);
        }
        // the tail round ends once the tail group drains idle
        if self.ctl.tail_round_open {
            let split = self.pool.num_engines() - self.tail_group();
            let idle = self.pool.engines()[split..]
                .iter()
                .all(|e| e.running() == 0 && e.queued() == 0);
            if idle {
                self.ctl.tail_round_open = false;
            }
        }
        Ok(rollouts.len())
    }

    fn harvest_candidates(&mut self) -> Result<Vec<HarvestItem>> {
        // a request can finish inside admit() itself (immediate EOS, or a
        // resumed straggler admitted at its cap) — collect those first so
        // they are harvested as completions, not partials
        for r in self.pool.drain_finished() {
            self.ctl.buffer.record_finished(&r);
        }
        let (mut partials, queued) = self.pool.terminate_all(self.state.version);
        partials.sort_by(|a, b| {
            b.response
                .len()
                .cmp(&a.response.len())
                .then(a.request.rid.cmp(&b.request.rid))
        });
        self.stash.clear();
        let mut items = Vec::with_capacity(partials.len() + queued.len());
        for r in partials {
            items.push(HarvestItem {
                rid: r.request.rid,
                progress: r.response.len(),
                queued: false,
            });
            self.stash.insert(r.request.rid, r);
        }
        for q in queued {
            items.push(HarvestItem { rid: q.rid, progress: 0, queued: true });
        }
        Ok(items)
    }

    fn resolve(&mut self, item: &HarvestItem, action: HarvestAction) -> Result<()> {
        let buffer = &mut self.ctl.buffer;
        match (self.stash.remove(&item.rid), action) {
            (Some(r), HarvestAction::Clip) => buffer.record_clipped(&r),
            (Some(r), HarvestAction::Restart) => buffer.record_terminated(&r, Mode::OnPolicy),
            (Some(r), HarvestAction::Resume | HarvestAction::Requeue) => {
                buffer.record_terminated(&r, Mode::Partial)
            }
            (Some(r), HarvestAction::Drop) => {
                buffer.record_terminated(&r, Mode::OnPolicy);
                self.ctl.discarded += buffer.consume_untrained(&[r.request.rid]) as u64;
            }
            (None, HarvestAction::Drop) => {
                buffer.record_requeued(item.rid);
                self.ctl.discarded += buffer.consume_untrained(&[item.rid]) as u64;
            }
            (None, _) => buffer.record_requeued(item.rid),
        }
        debug_assert!(self.ctl.buffer.check_invariants().is_ok());
        Ok(())
    }

    fn preempt(&mut self, engine: usize, lane: usize) -> Result<()> {
        self.pool.preempt(engine, lane, self.state.version);
        Ok(())
    }

    fn train(&mut self, rids: &[u64]) -> Result<()> {
        // pipelined mode: harvest the previous in-flight update FIRST —
        // its result defines the version this update enters at, and the
        // rendezvous keeps at most one update in flight
        if self.pipeline.as_ref().is_some_and(|p| p.in_flight() > 0) {
            let out = self.pipeline.as_mut().expect("checked above").wait();
            self.install(out)?;
        }
        let v_enter = self.state.version;
        let out = self
            .ctl
            .buffer
            .consume_bounded(rids, v_enter, self.staleness_cap);
        self.ctl.discarded += out.dropped.len() as u64;
        self.stale_resyncs += out.resynced.len() as u64;
        self.last_staleness.clear();
        for e in &out.entries {
            let st = entry_staleness(e, v_enter);
            self.last_staleness.insert(e.rid, st);
            *self.staleness_hist.entry(st).or_insert(0) += 1;
        }
        // a bounced batch still burns its slot in the update budget — the
        // policy already observed UpdateDone, and the re-synced samples
        // come back through a later harvest
        self.issued += 1;
        if out.entries.is_empty() {
            debug_assert!(self.ctl.buffer.check_invariants().is_ok());
            return Ok(());
        }
        // grading stays on this thread: the verifier reads the dataset,
        // the worker should only ever run train_step
        let rewards: Vec<Reward> = out
            .entries
            .iter()
            .map(|e| {
                self.ctl
                    .task
                    .verify(&self.ctl.dataset.train[e.problem_idx], &e.partial)
            })
            .collect();
        match self.pipeline.as_mut() {
            Some(p) => p.issue((out.entries, rewards)),
            None => {
                let trainer = self.trainer.as_mut().expect("serial path has a trainer");
                let log = trainer.update(self.state, &out.entries, &rewards)?;
                self.record_update_log(log)?;
            }
        }
        debug_assert!(self.ctl.buffer.check_invariants().is_ok());
        Ok(())
    }

    fn staleness_of(&self, rid: u64) -> Option<u64> {
        self.last_staleness.get(&rid).copied()
    }

    fn barrier(&mut self) -> Result<()> {
        self.ctl.buffer.clear_consumed();
        Ok(())
    }

    fn exhausted(&self) -> bool {
        self.issued >= self.max_updates
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduler_kind_parse_name_round_trip() {
        for kind in SchedulerKind::ALL {
            assert_eq!(SchedulerKind::parse(kind.name()), Some(kind),
                       "round trip failed for {}", kind.name());
        }
        // aliases
        assert_eq!(SchedulerKind::parse("on-policy"),
                   Some(SchedulerKind::SortedOnPolicy));
        assert_eq!(SchedulerKind::parse("partial"),
                   Some(SchedulerKind::SortedPartial));
        assert_eq!(SchedulerKind::parse("async-update"),
                   Some(SchedulerKind::AsyncUpdate));
        assert_eq!(SchedulerKind::parse("definitely-not-a-scheduler"), None);
    }

    #[test]
    fn valid_names_lists_every_variant() {
        let names = SchedulerKind::valid_names();
        for kind in SchedulerKind::ALL {
            assert!(names.contains(kind.name()),
                    "{} missing from valid_names(): {names}", kind.name());
        }
        assert!(names.contains("async"), "new scheduler must be advertised");
    }

    #[test]
    fn resumes_partials_only_for_partial_modes() {
        assert!(SchedulerKind::SortedPartial.resumes_partials());
        assert!(SchedulerKind::AsyncUpdate.resumes_partials());
        assert!(!SchedulerKind::SortedOnPolicy.resumes_partials());
        assert!(!SchedulerKind::Baseline.resumes_partials());
        assert!(!SchedulerKind::PostHocSort.resumes_partials());
        assert!(!SchedulerKind::NoGroupedRollout.resumes_partials());
    }
}
