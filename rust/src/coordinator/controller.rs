//! The SortedRL length-aware controller (paper §3) + baseline schedulers.
//!
//! One controller drives the whole RL loop: it pulls prompts from the
//! dataloader under the grouped cache-aware loading rule, oversubscribes
//! the rollout engine, early-terminates on the batching threshold (ready
//! trajectories >= update batch), harvests completed rollouts in completion
//! (== length) order, scavenges interrupted ones per the off-policiness
//! mode, and feeds selectively-composed batches to the trainer.
//!
//! Scheduler variants cover every strategy the paper evaluates:
//!   * `SortedOnPolicy` / `SortedPartial` — SortedRL's two modes (§3.2)
//!   * `Baseline`   — large rollout batch, sync barrier, k sequential
//!     off-policy updates (the canonical VeRL-style pipeline)
//!   * `PostHocSort` — ablation: baseline + sort by length before updating
//!   * `NoGroupedRollout` — ablation: oversubscription without the group
//!     barrier (biases training to short responses; Fig. 6a)

use crate::coordinator::buffer::{Lifecycle, Mode, RolloutBuffer};
use crate::coordinator::trainer::{Trainer, UpdateLog};
use crate::data::{DataLoader, Dataset};
use crate::metrics::PhaseClock;
use crate::rl::advantage::AdvantageKind;
use crate::rollout::EngineConfig;
use crate::runtime::{ParamState, Runtime};
use crate::sched::{DispatchPolicy, EnginePool, PoolConfig, PredictorKind};
use crate::tasks::{Reward, Task};
use anyhow::Result;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    SortedOnPolicy,
    SortedPartial,
    Baseline,
    PostHocSort,
    NoGroupedRollout,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "sorted-on-policy" | "on-policy" => Self::SortedOnPolicy,
            "sorted-partial" | "partial" => Self::SortedPartial,
            "baseline" => Self::Baseline,
            "post-hoc-sort" => Self::PostHocSort,
            "no-grouped" => Self::NoGroupedRollout,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::SortedOnPolicy => "sorted-on-policy",
            Self::SortedPartial => "sorted-partial",
            Self::Baseline => "baseline",
            Self::PostHocSort => "post-hoc-sort",
            Self::NoGroupedRollout => "no-grouped",
        }
    }
}

#[derive(Debug, Clone)]
pub struct LoopConfig {
    pub scheduler: SchedulerKind,
    /// b: prompts per rollout batch.
    pub rollout_prompts: usize,
    /// n: prompt batches per group (sorted modes; pool = n*b prompts).
    pub group_size: usize,
    /// G: responses sampled per prompt.
    pub samples_per_prompt: usize,
    /// Trajectories per logical update (advantage-normalization scope).
    pub update_batch: usize,
    pub max_updates: usize,
    pub lr: f32,
    pub temperature: f32,
    pub seed: u64,
    pub adv: AdvantageKind,
    /// Cap on generated tokens per response.
    pub max_new: usize,
    /// Evaluate every k updates (0 = never).
    pub eval_every: usize,
    /// Evaluate on at most this many held-out problems.
    pub eval_limit: usize,
    pub verbose: bool,
    /// Engines in the rollout pool (each with its own lanes + KV cache).
    pub num_engines: usize,
    /// Length predictor driving admission order / straggler detection.
    pub predictor: PredictorKind,
    /// How the pool places queued requests onto engines.
    pub dispatch: DispatchPolicy,
}

impl Default for LoopConfig {
    fn default() -> Self {
        Self {
            scheduler: SchedulerKind::SortedOnPolicy,
            rollout_prompts: 8,
            group_size: 4,
            samples_per_prompt: 2,
            update_batch: 16,
            max_updates: 50,
            lr: 1e-3,
            temperature: 1.0,
            seed: 0,
            adv: AdvantageKind::ReinforcePlusPlus,
            max_new: 160,
            eval_every: 10,
            eval_limit: 64,
            verbose: false,
            num_engines: 1,
            predictor: PredictorKind::History,
            dispatch: DispatchPolicy::LeastLoaded,
        }
    }
}

/// One row of the training telemetry (drives Figs. 3/4/6/9).
#[derive(Debug, Clone)]
pub struct LogRow {
    pub update: UpdateLog,
    pub epochs: f64,
    pub rollout_tokens: u64,
    pub rollout_secs: f64,
    pub eval: Option<EvalResult>,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct EvalResult {
    /// Mean total reward normalized by Reward::MAX (the "validation score").
    pub score: f64,
    pub accuracy: f64,
    pub format_rate: f64,
    pub mean_resp_len: f64,
}

/// Aggregated outcome of a training run.
pub struct RunResult {
    pub rows: Vec<LogRow>,
    pub final_eval: EvalResult,
    pub phase_clock: PhaseClock,
    /// (bubble_ratio, mean_occupancy) aggregated over rollout phases.
    pub bubble_ratio: f64,
    pub total_rollout_tokens: u64,
    /// Trajectories discarded without training (no-grouped ablation).
    pub discarded: u64,
}

pub struct Controller<'rt> {
    rt: &'rt Runtime,
    task: Box<dyn Task>,
    dataset: Dataset,
    loader: DataLoader,
    cfg: LoopConfig,
    buffer: RolloutBuffer,
    // occupancy aggregation across engine phases
    idle_area: f64,
    busy_span: f64,
    rollout_tokens: u64,
    discarded: u64,
}

impl<'rt> Controller<'rt> {
    pub fn new(rt: &'rt Runtime, task: Box<dyn Task>, dataset: Dataset,
               cfg: LoopConfig) -> Self {
        let loader = DataLoader::new(dataset.train.len(), cfg.seed ^ 0x11);
        Controller {
            rt,
            task,
            dataset,
            loader,
            cfg,
            buffer: RolloutBuffer::new(),
            idle_area: 0.0,
            busy_span: 0.0,
            rollout_tokens: 0,
            discarded: 0,
        }
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    fn engine_cfg(&self, greedy: bool) -> EngineConfig {
        EngineConfig {
            temperature: self.cfg.temperature,
            greedy,
            seed: self.cfg.seed,
        }
    }

    /// Build the rollout engine pool. `preempt` enables APRIL-style
    /// straggler requeue (partial mode only — on-policy semantics would
    /// discard the preempted tokens anyway).
    fn make_pool(&self, greedy: bool, preempt: bool) -> EnginePool<'rt> {
        EnginePool::new(self.rt, self.engine_cfg(greedy), PoolConfig {
            num_engines: self.cfg.num_engines.max(1),
            dispatch: self.cfg.dispatch,
            predictor: self.cfg.predictor,
            preempt,
            ..PoolConfig::default()
        })
    }

    fn effective_max_new(&self) -> usize {
        // keep prompt + response inside the training unroll T
        let t = self.rt.manifest.shapes.train_seq;
        let max_prompt = self
            .dataset
            .train
            .iter()
            .map(|p| p.prompt.len())
            .max()
            .unwrap_or(0);
        self.cfg.max_new.min(t.saturating_sub(max_prompt + 1))
    }

    fn load_prompts(&mut self, n_prompts: usize) {
        let max_new = self.effective_max_new();
        for idx in self.loader.next_batch(n_prompts) {
            let p = &self.dataset.train[idx];
            for _ in 0..self.cfg.samples_per_prompt {
                self.buffer.load_prompt(idx, p.id, p.prompt.clone(), max_new);
            }
        }
    }

    fn absorb_engine_occupancy(&mut self, pool: &EnginePool) {
        let (idle, busy, tokens) = pool.occupancy();
        self.idle_area += idle;
        self.busy_span += busy;
        self.rollout_tokens += tokens;
        if self.cfg.verbose && pool.score.count() > 0 {
            eprintln!(
                "[pool] predictor {}: {} scored, MAE {:.1} tok, tau {:.3}; \
                 {} preempted",
                self.cfg.predictor.name(),
                pool.score.count(),
                pool.score.mae(),
                pool.score.kendall_tau(),
                pool.preempted()
            );
        }
    }

    /// Aggregate bubble ratio over every rollout phase so far.
    pub fn bubble_ratio(&self) -> f64 {
        if self.busy_span == 0.0 {
            0.0
        } else {
            self.idle_area / self.busy_span
        }
    }

    // ------------------------------------------------------------------
    // evaluation (greedy)
    // ------------------------------------------------------------------

    pub fn evaluate(&self, state: &ParamState) -> Result<EvalResult> {
        let max_new = self.effective_max_new();
        let problems: Vec<(usize, &crate::tasks::Problem)> = self
            .dataset
            .eval
            .iter()
            .take(self.cfg.eval_limit)
            .enumerate()
            .collect();
        if problems.is_empty() {
            return Ok(EvalResult::default());
        }
        let mut engine = self.make_pool(true, false);
        engine.submit(problems.iter().map(|(i, p)| {
            crate::rollout::Request::fresh(*i as u64, *i, p.id, p.prompt.clone(), max_new)
        }));
        let rollouts = engine.run_to_completion(state)?;
        let mut score = 0.0;
        let mut acc = 0.0;
        let mut fmt = 0.0;
        let mut len = 0.0;
        for r in &rollouts {
            let p = problems[r.request.problem_idx].1;
            let reward = self.task.verify(p, &r.response);
            score += reward.total() / Reward::MAX;
            acc += reward.correct as u8 as f64;
            fmt += reward.format_ok as u8 as f64;
            len += r.response.len() as f64;
        }
        let n = rollouts.len() as f64;
        Ok(EvalResult {
            score: score / n,
            accuracy: acc / n,
            format_rate: fmt / n,
            mean_resp_len: len / n,
        })
    }

    // ------------------------------------------------------------------
    // main loop
    // ------------------------------------------------------------------

    pub fn run(&mut self, state: &mut ParamState) -> Result<RunResult> {
        let mut trainer = Trainer::new(self.rt, self.cfg.adv, self.cfg.lr);
        let mut rows: Vec<LogRow> = Vec::new();
        let mut phase_clock = PhaseClock::default();
        let train_secs_at_start = self.rt.stats_snapshot().train_secs;

        while trainer.updates() < self.cfg.max_updates {
            match self.cfg.scheduler {
                SchedulerKind::SortedOnPolicy => {
                    self.run_group(state, &mut trainer, Mode::OnPolicy, &mut rows,
                                   &mut phase_clock)?;
                }
                SchedulerKind::SortedPartial => {
                    self.run_group(state, &mut trainer, Mode::Partial, &mut rows,
                                   &mut phase_clock)?;
                }
                SchedulerKind::Baseline => {
                    self.run_baseline(state, &mut trainer, false, &mut rows,
                                      &mut phase_clock)?;
                }
                SchedulerKind::PostHocSort => {
                    self.run_baseline(state, &mut trainer, true, &mut rows,
                                      &mut phase_clock)?;
                }
                SchedulerKind::NoGroupedRollout => {
                    self.run_no_grouped(state, &mut trainer, &mut rows,
                                        &mut phase_clock)?;
                }
            }
        }

        phase_clock.update = self.rt.stats_snapshot().train_secs - train_secs_at_start;
        let final_eval = self.evaluate(state)?;
        Ok(RunResult {
            rows,
            final_eval,
            phase_clock,
            bubble_ratio: self.bubble_ratio(),
            total_rollout_tokens: self.rollout_tokens,
            discarded: self.discarded,
        })
    }

    fn log_update(&mut self, rows: &mut Vec<LogRow>, state: &ParamState,
                  log: UpdateLog, engine_secs: f64) -> Result<()> {
        let eval = if self.cfg.eval_every > 0 && log.update_idx % self.cfg.eval_every == 0 {
            Some(self.evaluate(state)?)
        } else {
            None
        };
        if self.cfg.verbose {
            let ev = eval
                .map(|e| format!(" | eval score {:.3} acc {:.3} len {:.1}",
                                 e.score, e.accuracy, e.mean_resp_len))
                .unwrap_or_default();
            eprintln!(
                "upd {:>4} v{:<4} reward {:+.3} acc {:.2} fmt {:.2} len {:>5.1} stale {:.2} kl {:+.4}{}",
                log.update_idx, log.policy_version, log.mean_reward, log.accuracy,
                log.format_rate, log.mean_resp_len, log.mean_staleness,
                log.stats.approx_kl, ev
            );
        }
        rows.push(LogRow {
            update: log,
            epochs: self.loader.epochs_elapsed(),
            rollout_tokens: self.rollout_tokens,
            rollout_secs: engine_secs,
            eval,
        });
        Ok(())
    }

    /// SortedRL (both modes): one group = n*b prompts, consumed fully
    /// before new prompts load (cache-aware loading, §3.1).
    fn run_group(&mut self, state: &mut ParamState, trainer: &mut Trainer,
                 mode: Mode, rows: &mut Vec<LogRow>,
                 phase_clock: &mut PhaseClock) -> Result<()> {
        let pool = self.cfg.group_size * self.cfg.rollout_prompts;
        self.load_prompts(pool);
        let mut engine = self.make_pool(false, mode == Mode::Partial);

        while !self.buffer.all_consumed() && trainer.updates() < self.cfg.max_updates {
            // dispatch everything schedulable (oversubscription)
            let rids = self.buffer.schedulable();
            if !rids.is_empty() {
                engine.submit(self.buffer.dispatch(&rids));
            }
            let unconsumed = self.buffer.len() - self.buffer.count(Lifecycle::Consumed);
            let quota = self.cfg.update_batch.min(unconsumed);
            // On-policy fires once most of the quota completed and clips the
            // top-progress runners to fill the batch (waiting for the last
            // completions is where discarded-progress waste piles up);
            // partial waits for full completions (resume is free).
            let threshold = match mode {
                Mode::OnPolicy => (quota * 3 / 4).max(1),
                Mode::Partial => quota,
            };
            let final_wave = unconsumed <= self.cfg.update_batch;
            let occ_floor = (engine.lane_count() * 3 / 4).max(1);
            // generate until the batching threshold fires or the pool drains
            loop {
                engine.admit(state)?;
                if engine.running() == 0 && engine.queued() == 0 {
                    break;
                }
                engine.step(state)?;
                for r in engine.drain_finished() {
                    self.buffer.record_finished(&r);
                }
                let ready = self.buffer.count(Lifecycle::Ready);
                if ready >= threshold && !final_wave {
                    break; // early termination (batching threshold)
                }
                if final_wave && engine.queued() == 0 && engine.running() < occ_floor {
                    break; // batching floor: clip the stragglers
                }
            }
            // a request can finish inside admit() itself (immediate EOS, or
            // a resumed straggler admitted at its cap) right before the
            // loop breaks — drain once more so it isn't lost in the engine
            for r in engine.drain_finished() {
                self.buffer.record_finished(&r);
            }
            // harvest: terminate in-flight, clip or scavenge per mode
            let (mut partials, queued) = engine.terminate_all(state.version);
            partials.sort_by(|a, b| b.response.len().cmp(&a.response.len()));
            let mut ready_count = self.buffer.count(Lifecycle::Ready);
            for r in &partials {
                let clip = !r.response.is_empty()
                    && (final_wave
                        || (mode == Mode::OnPolicy && ready_count < quota));
                if clip {
                    self.buffer.record_clipped(r);
                    ready_count += 1;
                } else {
                    self.buffer.record_terminated(r, mode);
                }
            }
            if final_wave {
                // never-scheduled leftovers at group end are dropped
                let stragglers: Vec<u64> = queued.iter().map(|q| q.rid).collect();
                for q in queued {
                    self.buffer.record_requeued(q.rid);
                }
                let leftover: Vec<u64> = self
                    .buffer
                    .schedulable()
                    .into_iter()
                    .filter(|rid| stragglers.contains(rid))
                    .collect();
                self.discarded += self.buffer.consume_untrained(&leftover) as u64;
            } else {
                for q in queued {
                    self.buffer.record_requeued(q.rid);
                }
            }
            debug_assert!(self.buffer.check_invariants().is_ok());

            // consume up to update_batch ready trajectories, completion order
            let ready = self.buffer.ready_rids();
            if ready.is_empty() {
                break; // nothing finished (shouldn't happen with sane caps)
            }
            let take: Vec<u64> = ready
                .into_iter()
                .take(self.cfg.update_batch)
                .collect();
            let entries = self.buffer.consume(&take);
            let rewards = trainer.grade(self.task.as_ref(), &self.dataset.train, &entries);
            let log = trainer.update(state, &entries, &rewards)?;
            self.log_update(rows, state, log, engine.host_secs())?;
        }
        self.absorb_engine_occupancy(&engine);
        phase_clock.rollout += engine.host_secs();
        self.buffer.clear_consumed();
        Ok(())
    }

    /// Canonical baseline: R-prompt rollout batch, sync barrier, then
    /// ceil(R*G / U) sequential updates on the same (aging) data.
    /// `sort_post_hoc` = the Fig.6a ablation.
    fn run_baseline(&mut self, state: &mut ParamState, trainer: &mut Trainer,
                    sort_post_hoc: bool, rows: &mut Vec<LogRow>,
                    phase_clock: &mut PhaseClock) -> Result<()> {
        // baseline consumes group_size*b prompts per iteration so data
        // volume matches the sorted runs
        let pool = self.cfg.group_size * self.cfg.rollout_prompts;
        self.load_prompts(pool);
        let mut engine = self.make_pool(false, false);
        let rids = self.buffer.schedulable();
        engine.submit(self.buffer.dispatch(&rids));
        let rollouts = engine.run_to_completion(state)?;
        for r in &rollouts {
            self.buffer.record_finished(r);
        }
        self.absorb_engine_occupancy(&engine);
        phase_clock.rollout += engine.host_secs();

        let mut order: Vec<u64> = if sort_post_hoc {
            // sort by response length ascending AFTER full generation
            let mut v: Vec<(usize, u64)> = rollouts
                .iter()
                .map(|r| (r.response.len(), r.request.rid))
                .collect();
            v.sort();
            v.into_iter().map(|(_, rid)| rid).collect()
        } else {
            rollouts.iter().map(|r| r.request.rid).collect()
        };

        while !order.is_empty() && trainer.updates() < self.cfg.max_updates {
            let take: Vec<u64> = order
                .drain(..self.cfg.update_batch.min(order.len()))
                .collect();
            let entries = self.buffer.consume(&take);
            let rewards = trainer.grade(self.task.as_ref(), &self.dataset.train, &entries);
            let log = trainer.update(state, &entries, &rewards)?;
            self.log_update(rows, state, log, engine.host_secs())?;
        }
        self.buffer.clear_consumed();
        Ok(())
    }

    /// Ablation (Fig. 6a): oversubscription + early termination WITHOUT the
    /// grouped loading barrier: the pool is continuously topped up with
    /// fresh prompts and interrupted generations are abandoned, so training
    /// data biases hard toward short responses.
    fn run_no_grouped(&mut self, state: &mut ParamState, trainer: &mut Trainer,
                      rows: &mut Vec<LogRow>, phase_clock: &mut PhaseClock)
                      -> Result<()> {
        let pool = self.cfg.group_size * self.cfg.rollout_prompts;
        let mut engine = self.make_pool(false, false);
        let mut iterations = 0usize;
        while trainer.updates() < self.cfg.max_updates && iterations < 10_000 {
            iterations += 1;
            // top up: no barrier — fresh prompts stream in immediately
            let deficit = pool.saturating_sub(self.buffer.count(Lifecycle::Fresh));
            if deficit > 0 {
                self.load_prompts(deficit / self.cfg.samples_per_prompt.max(1) + 1);
            }
            let rids = self.buffer.schedulable();
            engine.submit(self.buffer.dispatch(&rids));
            loop {
                engine.admit(state)?;
                if engine.running() == 0 && engine.queued() == 0 {
                    break;
                }
                engine.step(state)?;
                for r in engine.drain_finished() {
                    self.buffer.record_finished(&r);
                }
                if self.buffer.count(Lifecycle::Ready) >= self.cfg.update_batch {
                    break;
                }
            }
            // catch completions that happened inside the final admit()
            for r in engine.drain_finished() {
                self.buffer.record_finished(&r);
            }
            let (partials, queued) = engine.terminate_all(state.version);
            // abandon interrupted generations entirely (prompt starvation)
            for r in &partials {
                self.buffer.record_terminated(r, Mode::OnPolicy);
            }
            let abandoned: Vec<u64> = partials.iter().map(|r| r.request.rid).collect();
            self.buffer.discard(&abandoned);
            self.discarded += abandoned.len() as u64;
            for q in queued {
                self.buffer.record_requeued(q.rid);
            }
            let ready = self.buffer.ready_rids();
            if ready.is_empty() {
                continue;
            }
            let take: Vec<u64> = ready.into_iter().take(self.cfg.update_batch).collect();
            let entries = self.buffer.consume(&take);
            let rewards = trainer.grade(self.task.as_ref(), &self.dataset.train, &entries);
            let log = trainer.update(state, &entries, &rewards)?;
            self.log_update(rows, state, log, engine.host_secs())?;
            self.buffer.clear_consumed();
        }
        self.absorb_engine_occupancy(&engine);
        phase_clock.rollout += engine.host_secs();
        Ok(())
    }
}
