//! `sortedrl` — leader entrypoint + CLI.
//!
//! Subcommands:
//!   train   run one RL training loop (any scheduler) on a task
//!   exp     regenerate a paper table/figure (fig1a..fig9b, tab1, all)
//!   sim     quick simulator sweep (throughput/bubble for a workload)
//!   info    print artifact manifest / platform info
//!
//! No clap offline — a small hand-rolled parser; every flag has the form
//! `--key value` (or `--flag` for booleans).

use anyhow::{bail, Context, Result};
use sortedrl::coordinator::{Controller, LoopConfig, SchedulerKind};
use sortedrl::data::Dataset;
use sortedrl::exp::{self, ExpContext, Scale};
use sortedrl::rl::advantage::AdvantageKind;
use sortedrl::rollout::kv::{KvConfig, KvMode, DEFAULT_KV_PAGE, MAX_KV_PAGE};
use sortedrl::runtime::Runtime;
use sortedrl::sched::{DispatchPolicy, EngineSpec, PredictorKind, TailConfig};
use sortedrl::sim::{longtail_workload, PoolSimOpts, SimCore, SimMode, SimRun};
use sortedrl::tasks::logic::LogicTask;
use sortedrl::tasks::math::MathTask;
use sortedrl::tasks::Task;
use sortedrl::util::json::{num, obj};
use sortedrl::workload::{emit_trace, generate_trace, Arrival, ArrivalSpec};
use std::collections::HashMap;
use std::path::PathBuf;

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    fn get_opt_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).with_context(|| format!("--{key} {v}")),
        }
    }
}

/// Parse the shared tracing flag pair: `--trace-out FILE` and `--slo MS`.
fn parse_tracing(args: &Args) -> Result<(Option<PathBuf>, Option<f64>)> {
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let slo_ms = args.get_opt_f64("slo")?;
    if let Some(ms) = slo_ms {
        if !ms.is_finite() || ms <= 0.0 {
            bail!("--slo {ms} must be a positive latency in milliseconds");
        }
    }
    Ok((trace_out, slo_ms))
}

const USAGE: &str = "\
sortedrl — online length-aware scheduling for RL training of LLMs

USAGE:
  sortedrl train [--task logic|math] [--scheduler baseline|on-policy|partial|
                 post-hoc-sort|no-grouped|async] [--updates N] [--rollout-prompts b]
                 [--group-size n] [--samples-per-prompt G] [--update-batch U]
                 [--lr F] [--max-new N] [--seed N] [--scale ci|small|paper]
                 [--engines N] [--predictor oracle|history|bucket]
                 [--dispatch rr|least-loaded|sjf] [--steal] [--kv-budget TOK]
                 [--kv-mode reserve|paged] [--kv-page TOK] [--staleness N]
                 [--tail-threshold TOK] [--tail-engines N] [--engine-spec SPEC]
                 [--trace-out FILE] [--slo MS]
                 [--artifacts DIR] [--tag TAG] [--no-warm-start]
  sortedrl exp <fig1a|fig1b|fig1c|fig3|fig4|fig5|fig6a|fig6b|fig9a|fig9b|tab1|
                pool|all-sim|all> [--scale ci|small|paper] [--out DIR] [--seed N]
                [--arrival SPEC]   (open-loop section of `exp pool`)
  sortedrl sim [--n 512] [--cap 8192] [--queue 128] [--update-batch 128]
               [--engines N] [--predictor oracle|history|bucket]
               [--dispatch rr|least-loaded|sjf] [--steal] [--kv-budget TOK]
               [--kv-mode reserve|paged] [--kv-page TOK] [--staleness N]
               [--tail-threshold TOK] [--tail-engines N] [--engine-spec SPEC]
               [--sim-core event|reference] [--report-out FILE]
               [--arrival batch|poisson:RATE|bursty:HI,LO,FLIP|
                          diurnal:BASE,AMP,PERIOD|trace:FILE]
               [--trace-out FILE] [--slo MS] [--slo-out FILE]
  sortedrl workload trace-gen [--out FILE] [--tenants 3] [--rate 8]
               [--horizon 60] [--cap 8192] [--seed N]
  sortedrl info [--artifacts DIR] [--tag TAG]

Pool defaults (train & sim): --engines 1, --predictor history,
--dispatch least-loaded.  --steal lets idle engines pull queued work or
whole lanes from loaded peers.  --kv-budget TOK caps each engine's KV
usage (0 = unlimited); --kv-mode reserve charges prompt + generation cap
per admitted lane, --kv-mode paged charges only the context actually
generated, in --kv-page token pages, admitting on predicted lengths with
shed/throttle backpressure when estimates undershoot.

--staleness N (train & sim) hard-caps the off-policy degree of async
training: every sample entering an update must be at most N weight
versions older than the update consuming it, enforced at consume time
(an over-stale sample is re-synced — regenerated under the current
weights — once, and dropped on a repeat violation), so the reported
max staleness is provably <= N.  N also becomes the async scheduler's
re-sync window (the built-in constant is only the derived default).
Omit the flag for the legacy unbounded window; 0 is rejected.

--sim-core picks the pool stepper: event (default) fuses silent decode
spans through an event heap — same decisions, orders of magnitude fewer
host ops; reference replays the original per-iteration stepper (the
differential oracle).  An enabled tracer always uses reference.

Tracing (train & sim): --trace-out FILE writes a Chrome-trace-event JSON
of the run (open at https://ui.perfetto.dev); --slo MS records per-request
spans and reports TTFT/TPOT/e2e p50/p99 plus goodput against an
end-to-end latency SLO in milliseconds.  Either flag enables recording;
without both, tracing code is compiled in but never touched.

Tail rounds (train & sim): --tail-threshold TOK defers every request
whose predicted response length exceeds TOK into batched tail rounds on
the top --tail-engines engines (default 1), elastically borrowing lanes
and KV budget from the head group at round boundaries and giving them
back when the round drains.  Needs a token-count predictor
(oracle|history) and at least 2 engines so one stays in the head group.

--engine-spec declares a heterogeneous fleet as comma-separated
[Nx]LANES:KV[:SPEED] atoms — e.g. '2x8:4096:2,4:65536:0.5' is two fast
8-lane engines with 4096-token KV plus one half-speed 4-lane engine with
a 65536-token budget ('max' = unlimited KV).  The spec replaces --queue's
uniform split, --engines defaults to the fleet size, and SPEED weighs
routing/stealing decisions (sim engines also decode at that relative
speed; live engines decode at hardware speed).

--report-out FILE (sim, closed loop) dumps the partial-mode pool report
as JSON (throughput, bubble split, tail-round and repartition counters).

--arrival switches sim from the closed loop (batch: every request
schedulable at t=0, the default — byte-identical to runs predating the
flag) to an open-loop request stream: Poisson at RATE req/s, a
Markov-modulated on/off burst process, a sinusoidal diurnal rate, or a
multi-tenant JSONL trace (one {\"t\",\"tenant\",\"prompt_len\",\"cap\"}
object per line — `workload trace-gen` emits synthetic ones).  Open-loop
latencies are arrival-relative (queueing included); with --slo the report
adds per-tenant rollups and a Jain fairness index, and --slo-out FILE
dumps that summary as JSON.
";

fn parse_predictor(args: &Args) -> Result<PredictorKind> {
    PredictorKind::parse(args.get("predictor").unwrap_or("history"))
        .context("--predictor oracle|history|bucket")
}

/// Parse and validate the KV flag triple (`--kv-mode`, `--kv-budget`,
/// `--kv-page`).  `--kv-budget 0` (or absent) = unlimited.  Nonsense
/// combinations are rejected here with an actionable one-liner instead of
/// starving every engine at runtime (the empty-engine escape would avoid
/// a literal deadlock, but one-lane-at-a-time is never what was meant).
fn parse_kv(args: &Args) -> Result<KvConfig> {
    let mode = KvMode::parse(args.get("kv-mode").unwrap_or("reserve"))
        .context("--kv-mode reserve|paged")?;
    let page = args.get_usize("kv-page", DEFAULT_KV_PAGE)?;
    if page == 0 {
        bail!("--kv-page must be >= 1 token (default {DEFAULT_KV_PAGE}); \
               0 pages cannot hold any context");
    }
    if page > MAX_KV_PAGE {
        bail!("--kv-page {page} exceeds {MAX_KV_PAGE}; a page is a KV block \
               in tokens, not a budget — did you mean --kv-budget {page}?");
    }
    let v = args.get_usize("kv-budget", 0)?;
    let budget = if v == 0 { usize::MAX } else { v };
    // reserve mode never consults the page size, so only paged budgets
    // are checked against it (a reserve budget of any size stays valid —
    // its worst case is caught by the engines' empty-engine escape)
    if mode == KvMode::Paged && budget != usize::MAX && budget < page {
        bail!("--kv-budget {budget} cannot hold one prompt plus one \
               --kv-page {page} page; raise the budget, lower --kv-page, \
               or pass 0 for unlimited");
    }
    Ok(KvConfig { mode, budget, page })
}

/// Parse `--staleness N`, the off-policy-degree hard cap.  Absent = the
/// legacy unbounded-window behavior (`ASYNC_SYNC_EVERY` re-sync cadence,
/// no consume-time cap).  0 is rejected: a sample consumed in the same
/// version it was born has staleness 0, so a 0 cap would re-sync every
/// sample that survives a single update — an infinite regeneration loop,
/// never what was meant.
fn parse_staleness(args: &Args) -> Result<Option<usize>> {
    let Some(v) = args.get("staleness") else { return Ok(None) };
    let n: usize = v.parse().with_context(|| format!("--staleness {v}"))?;
    if n == 0 {
        bail!("--staleness must be >= 1 weight version (0 would bounce \
               every sample that outlives one update; omit the flag for \
               the unbounded legacy window)");
    }
    Ok(Some(n))
}

fn parse_dispatch(args: &Args) -> Result<DispatchPolicy> {
    // fallback matches LoopConfig::default() so flag-less CLI runs agree
    // with the examples, exp suites, and tests
    DispatchPolicy::parse(args.get("dispatch").unwrap_or("least-loaded"))
        .context("--dispatch rr|least-loaded|sjf")
}

/// Parse the tail-round flag pair (`--tail-threshold`, `--tail-engines`).
/// Rejects configurations that could only ever be inert: a rank-only
/// predictor stamps no token counts (nothing would classify as tail), and
/// a 1-engine fleet leaves no head group to borrow from.
fn parse_tail(args: &Args, predictor: PredictorKind, engines: usize)
              -> Result<Option<TailConfig>> {
    let Some(threshold) = args.get("tail-threshold") else {
        if args.get("tail-engines").is_some() {
            bail!("--tail-engines needs --tail-threshold TOK to define what \
                   counts as a tail request");
        }
        return Ok(None);
    };
    let threshold: usize = threshold
        .parse()
        .with_context(|| format!("--tail-threshold {threshold}"))?;
    let tail_engines = args.get_usize("tail-engines", 1)?;
    let tc = TailConfig { threshold, tail_engines };
    tc.validate()?;
    if predictor == PredictorKind::Bucket {
        bail!("--tail-threshold needs a token-count predictor \
               (--predictor oracle|history); bucket is rank-only, so no \
               request would ever classify as tail");
    }
    if engines < 2 {
        bail!("--tail-threshold needs --engines >= 2 (at least one engine \
               must stay in the head group)");
    }
    if tail_engines >= engines {
        bail!("--tail-engines {tail_engines} must leave a head engine \
               (--engines {engines})");
    }
    Ok(Some(tc))
}

/// Parse `--engine-spec` into a heterogeneous fleet, cross-validated
/// against the KV flags the way `--kv-budget` is: a paged per-engine
/// budget must hold at least one `--kv-page` page.
fn parse_specs(args: &Args, kv: &KvConfig) -> Result<Vec<EngineSpec>> {
    let Some(s) = args.get("engine-spec") else { return Ok(Vec::new()) };
    let fleet = EngineSpec::parse_fleet(s)?;
    if kv.mode == KvMode::Paged {
        for (i, sp) in fleet.iter().enumerate() {
            if sp.kv_budget != usize::MAX && sp.kv_budget < kv.page {
                bail!("--engine-spec engine {i}: paged kv budget {} cannot \
                       hold one --kv-page {} page; raise the budget, lower \
                       --kv-page, or use 'max'", sp.kv_budget, kv.page);
            }
        }
    }
    Ok(fleet)
}

/// `--engines` resolved against `--engine-spec`: the spec defines the
/// fleet size; an explicit `--engines` must agree with it.
fn resolve_engines(args: &Args, specs: &[EngineSpec]) -> Result<usize> {
    let default = if specs.is_empty() { 1 } else { specs.len() };
    let n = args.get_usize("engines", default)?;
    if n == 0 {
        bail!("--engines must be >= 1");
    }
    if !specs.is_empty() && n != specs.len() {
        bail!("--engines {n} disagrees with --engine-spec ({} engines)", specs.len());
    }
    Ok(n)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    let result = match cmd.as_str() {
        "train" => cmd_train(&args),
        "exp" => cmd_exp(&args),
        "sim" => cmd_sim(&args),
        "workload" => cmd_workload(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_runtime(args: &Args) -> Result<Runtime> {
    let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    Runtime::load(&dir, args.get("tag"))
}

fn cmd_train(args: &Args) -> Result<()> {
    // flag validation precedes artifact loading so `--staleness 0` (and
    // friends) fail on the flag, not on a missing artifacts/ directory
    let staleness = parse_staleness(args)?;
    let rt = load_runtime(args)?;
    eprintln!("platform: {}; artifacts tag: {}", rt.platform(), rt.manifest.tag);
    let scale = Scale::parse(args.get("scale").unwrap_or("small"))
        .context("--scale ci|small|paper")?;
    let ts = exp::suites::train_scale(scale);
    let task_name = args.get("task").unwrap_or("logic");
    let task: Box<dyn Task> = match task_name {
        "logic" => Box::new(LogicTask::default()),
        "math" => Box::new(MathTask),
        other => bail!("unknown task {other:?}"),
    };
    let scheduler = SchedulerKind::parse(args.get("scheduler").unwrap_or("on-policy"))
        .with_context(|| format!("--scheduler {}", SchedulerKind::valid_names()))?;
    let seed = args.get_u64("seed", 0)?;
    let kv = parse_kv(args)?;
    let specs = parse_specs(args, &kv)?;
    let num_engines = resolve_engines(args, &specs)?;
    let predictor = parse_predictor(args)?;
    let tail = parse_tail(args, predictor, num_engines)?;
    let (trace_out, slo_ms) = parse_tracing(args)?;
    let cfg = LoopConfig {
        scheduler,
        rollout_prompts: args.get_usize("rollout-prompts", ts.rollout_prompts)?,
        group_size: args.get_usize("group-size", ts.group_size)?,
        samples_per_prompt: args.get_usize("samples-per-prompt", ts.samples_per_prompt)?,
        update_batch: args.get_usize("update-batch", ts.update_batch)?,
        max_updates: args.get_usize("updates", ts.max_updates)?,
        lr: args.get_f32("lr", ts.lr_rl)?,
        temperature: args.get_f32("temperature", 1.0)?,
        seed,
        adv: AdvantageKind::ReinforcePlusPlus,
        max_new: args.get_usize("max-new", ts.max_new)?,
        eval_every: args.get_usize("eval-every", ts.eval_every)?,
        eval_limit: args.get_usize("eval-limit", ts.eval_limit)?,
        verbose: true,
        num_engines,
        predictor,
        dispatch: parse_dispatch(args)?,
        steal: args.get("steal").is_some(),
        kv_budget: kv.budget,
        kv_mode: kv.mode,
        kv_page: kv.page,
        trace_out,
        slo_ms,
        staleness,
        tail,
        engine_specs: specs,
    };
    let ds = Dataset::generate(task.as_ref(), ts.per_difficulty, 0.1, seed + 1);
    eprintln!("dataset: {} train / {} eval; scheduler: {}",
              ds.train.len(), ds.eval.len(), scheduler.name());
    eprintln!("pool: {} engine(s), predictor {}, dispatch {}, steal {}, \
               kv {} budget {} page {}",
              cfg.num_engines, cfg.predictor.name(), cfg.dispatch.name(),
              cfg.steal, cfg.kv_mode.name(),
              if cfg.kv_budget == usize::MAX { "unlimited".to_string() }
              else { cfg.kv_budget.to_string() },
              cfg.kv_page);
    if let Some(tc) = cfg.tail {
        eprintln!("tail rounds: threshold {} tokens, {} tail engine(s)",
                  tc.threshold, tc.tail_engines);
    }
    if !cfg.engine_specs.is_empty() {
        eprintln!("fleet: {}",
                  cfg.engine_specs.iter()
                      .map(|s| format!("{}:{}:{}", s.lanes,
                           if s.kv_budget == usize::MAX { "max".to_string() }
                           else { s.kv_budget.to_string() },
                           s.speed))
                      .collect::<Vec<_>>()
                      .join(","));
    }

    let mut state = rt.init(seed as i32)?;
    if args.get("no-warm-start").is_none() {
        let problems: Vec<&sortedrl::tasks::Problem> = ds.train.iter().collect();
        sortedrl::coordinator::sft_warm_start(
            &rt, &mut state, &problems, ts.sft_steps, ts.lr_sft, 20)?;
    }
    let mut ctl = Controller::new(&rt, task, ds, cfg);
    let result = ctl.run(&mut state)?;
    println!("\nfinal eval: score {:.3} accuracy {:.3} resp_len {:.1}",
             result.final_eval.score, result.final_eval.accuracy,
             result.final_eval.mean_resp_len);
    println!("rollout bubble ratio: {:.2}%", result.bubble_ratio * 100.0);
    if tail.is_some() {
        println!("tail rounds: {} ({} requests packed, {} repartitions); \
                  head bubble {:.2}% tail bubble {:.2}%",
                 result.tail_rounds, result.tail_admitted, result.repartitions,
                 result.head_bubble * 100.0, result.tail_bubble * 100.0);
    }
    println!("rollout tokens: {}; rollout secs {:.1}; update secs {:.1}",
             result.total_rollout_tokens, result.phase_clock.rollout,
             result.phase_clock.update);
    if scheduler == SchedulerKind::AsyncUpdate {
        println!("staleness: max {}{} | {} resyncs | hist {:?}",
                 result.max_staleness,
                 match staleness {
                     Some(n) => format!(" (cap {n})"),
                     None => String::new(),
                 },
                 result.stale_resyncs, result.staleness_hist);
    }
    if let Some(slo) = &result.slo {
        println!("slo: ttft p50 {:.3}s p99 {:.3}s | tpot p50 {:.4}s p99 {:.4}s | \
                  e2e p50 {:.3}s p99 {:.3}s | goodput {:.3}",
                 slo.ttft_p50, slo.ttft_p99, slo.tpot_p50, slo.tpot_p99,
                 slo.e2e_p50, slo.e2e_p99, slo.goodput);
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .context("exp needs a figure/table id (see --help)")?;
    let ctx = ExpContext {
        artifacts_dir: PathBuf::from(args.get("artifacts").unwrap_or("artifacts")),
        tag: args.get("tag").map(String::from),
        out_dir: PathBuf::from(args.get("out").unwrap_or("results")),
        scale: Scale::parse(args.get("scale").unwrap_or("small"))
            .context("--scale ci|small|paper")?,
        seed: args.get_u64("seed", 0)?,
        arrival: args.get("arrival").map(ArrivalSpec::parse).transpose()?,
    };
    let needs_rt = !matches!(which, "fig1a" | "fig1b" | "fig5" | "pool" | "all-sim");
    let rt = if needs_rt {
        Some(Runtime::load(&ctx.artifacts_dir, ctx.tag.as_deref())?)
    } else {
        None
    };
    match which {
        "fig1a" => exp::fig1::fig1a(&ctx)?,
        "fig1b" => exp::fig1::fig1b(&ctx)?,
        "fig1c" => {
            let lens = rt.as_ref().map(|rt| real_rollout_lengths(&ctx, rt)).transpose()?;
            exp::fig1::fig1c(&ctx, lens.as_deref())?;
        }
        "fig5" => exp::fig5::fig5(&ctx)?,
        "pool" => exp::suites::pool_suite(&ctx)?,
        "fig3" | "fig9a" => exp::suites::logic_suite(&ctx, rt.as_ref().unwrap())?,
        "fig4" | "tab1" => exp::suites::math_suite(&ctx, rt.as_ref().unwrap())?,
        "fig6a" => exp::suites::fig6a(&ctx, rt.as_ref().unwrap())?,
        "fig6b" => exp::suites::fig6b(&ctx, rt.as_ref().unwrap())?,
        "fig9b" => exp::suites::fig9b(&ctx, rt.as_ref().unwrap())?,
        "all-sim" => {
            exp::fig1::fig1a(&ctx)?;
            println!();
            exp::fig1::fig1b(&ctx)?;
            println!();
            exp::fig5::fig5(&ctx)?;
            println!();
            exp::suites::pool_suite(&ctx)?;
        }
        "all" => {
            exp::fig1::fig1a(&ctx)?;
            exp::fig1::fig1b(&ctx)?;
            let rt = rt.as_ref().unwrap();
            let lens = real_rollout_lengths(&ctx, rt)?;
            exp::fig1::fig1c(&ctx, Some(&lens))?;
            exp::fig5::fig5(&ctx)?;
            exp::suites::pool_suite(&ctx)?;
            exp::suites::logic_suite(&ctx, rt)?;
            exp::suites::fig6a(&ctx, rt)?;
            exp::suites::fig6b(&ctx, rt)?;
            exp::suites::math_suite(&ctx, rt)?;
            exp::suites::fig9b(&ctx, rt)?;
        }
        other => bail!("unknown experiment {other:?}"),
    }
    Ok(())
}

/// Sample real rollout lengths from a warm-started model (Fig. 1c's "real"
/// series).
fn real_rollout_lengths(ctx: &ExpContext, rt: &Runtime) -> Result<Vec<usize>> {
    use sortedrl::rollout::{Engine, EngineConfig, Request};
    let ts = exp::suites::train_scale(Scale::Ci);
    let (state, ds) = exp::suites::warm_start(rt, "logic", &ts, ctx.seed + 13)?;
    let mut engine = Engine::new(rt, EngineConfig {
        temperature: 1.0,
        greedy: false,
        seed: ctx.seed + 14,
        ..EngineConfig::default()
    });
    let n = 128.min(ds.train.len());
    engine.submit(ds.train.iter().take(n).enumerate().map(|(i, p)| {
        Request::fresh(i as u64, i, p.id, p.prompt.clone(), ts.max_new)
    }));
    let rollouts = engine.run_to_completion(&state)?;
    Ok(rollouts.iter().map(|r| r.response.len()).collect())
}

/// `workload trace-gen`: emit a synthetic multi-tenant arrival trace as
/// JSONL (stdout, or `--out FILE`) in the exact schema `--arrival
/// trace:FILE` replays.
fn cmd_workload(args: &Args) -> Result<()> {
    let sub = args
        .positional
        .first()
        .map(|s| s.as_str())
        .context("workload needs a subcommand: trace-gen")?;
    match sub {
        "trace-gen" => {
            let tenants = args.get_usize("tenants", 3)?;
            if tenants == 0 {
                bail!("--tenants must be >= 1");
            }
            let rate = args.get_opt_f64("rate")?.unwrap_or(8.0);
            if !rate.is_finite() || rate <= 0.0 {
                bail!("--rate must be a positive aggregate req/s");
            }
            let horizon = args.get_opt_f64("horizon")?.unwrap_or(60.0);
            if !horizon.is_finite() || horizon <= 0.0 {
                bail!("--horizon must be a positive duration in seconds");
            }
            let cap = args.get_usize("cap", 8192)?;
            if cap == 0 {
                bail!("--cap must be >= 1 token");
            }
            let seed = args.get_u64("seed", 0)?;
            let events = generate_trace(tenants, rate, horizon, cap, seed);
            let text = emit_trace(&events);
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, &text)
                        .with_context(|| format!("writing {path}"))?;
                    eprintln!("wrote {} arrivals ({} tenants, {horizon}s horizon) to {path}",
                              events.len(), tenants);
                }
                None => print!("{text}"),
            }
            Ok(())
        }
        other => bail!("unknown workload subcommand {other:?} (try trace-gen)"),
    }
}

fn cmd_sim(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 512)?;
    let cap = args.get_usize("cap", 8192)?;
    let u = args.get_usize("update-batch", 128)?;
    let seed = args.get_u64("seed", 0)?;
    let kv = parse_kv(args)?;
    let specs = parse_specs(args, &kv)?;
    let engines = resolve_engines(args, &specs)?;
    let q = if specs.is_empty() {
        let q = args.get_usize("queue", 128)?;
        if engines > q {
            bail!("--engines {engines} exceeds --queue {q} (each engine needs at least one lane)");
        }
        if q % engines != 0 {
            bail!("--queue {q} must be divisible by --engines {engines} \
                   (otherwise the 1-vs-N comparison runs unequal capacities)");
        }
        q
    } else {
        if args.get("queue").is_some() {
            bail!("--queue conflicts with --engine-spec (lane counts come \
                   from the spec)");
        }
        specs.iter().map(|s| s.lanes).sum()
    };
    if u == 0 {
        bail!("--update-batch must be >= 1");
    }
    let predictor = parse_predictor(args)?;
    let dispatch = parse_dispatch(args)?;
    let steal = args.get("steal").is_some();
    let staleness = parse_staleness(args)?;
    let tail = parse_tail(args, predictor, engines)?;
    let core = match args.get("sim-core") {
        Some(s) => SimCore::parse(s).context("--sim-core event|reference")?,
        None => SimCore::default(),
    };
    // the full pool-shaped knob set; the historical single-engine legs
    // below deliberately run `PoolSimOpts::default()`-shaped opts instead
    let opts = PoolSimOpts {
        engines,
        q_total: q,
        update_batch: u,
        dispatch,
        predictor,
        steal,
        kv_budget: kv.budget,
        kv_mode: kv.mode,
        kv_page: kv.page,
        core,
        staleness,
        tail,
        ..PoolSimOpts::default()
    };
    let arrival = match args.get("arrival") {
        Some(s) => ArrivalSpec::parse(s)?,
        None => ArrivalSpec::Batch,
    };
    if arrival.is_open_loop() {
        // open-loop stream: requests enter at their arrival instants —
        // a different experiment shape, reported by its own section
        let arrivals = arrival.build(n, cap, seed)?;
        return sim_open_loop(args, &arrivals, cap, q, u, opts, &specs);
    }
    let w = longtail_workload(n, cap, seed);
    println!("workload: {n} requests, cap {cap}, queue {q}, update batch {u}{}\n",
             match staleness {
                 Some(s) => format!(", staleness cap {s}"),
                 None => String::new(),
             });
    for (mode, label) in [(SimMode::Baseline, "baseline"),
                          (SimMode::SortedOnPolicy, "on-policy"),
                          (SimMode::SortedPartial, "partial"),
                          (SimMode::Async, "async")] {
        // identical to the historical `simulate()` shorthand when no cap
        // is set (same dispatch/predictor defaults, 1 engine)
        let r = SimRun::new(mode, PoolSimOpts {
            q_total: q,
            update_batch: u,
            staleness,
            ..PoolSimOpts::default()
        }).workload(&w).run();
        println!("{label:>10}: {:7.0} tok/s  bubble {:5.2}%  rollout {:7.1}s  \
                  total {:7.1}s  wasted {:8}  clipped {:3}  max-stale {:2}",
                 r.throughput, r.bubble_ratio * 100.0, r.rollout_time,
                 r.total_time, r.wasted_tokens, r.clipped, r.max_staleness);
    }
    if engines > 1 {
        println!("\npool: {engines} engines x {} lanes, predictor {}, dispatch {}, \
                  steal {steal} (1-engine vs {engines}-engine, same total capacity)",
                 q / engines, predictor.name(), dispatch.name());
        let mut telemetry = (0.0, 0.0);
        let mut stolen = (0u64, 0u64);
        let mut kv_stats = (0usize, 0u64, 0u64);
        let mut stale = (0u64, 0u64);
        let mut tail_stats = (0u64, 0u64, 0u64, 0.0f64, 0.0f64);
        for (mode, label) in [(SimMode::Baseline, "baseline"),
                              (SimMode::SortedOnPolicy, "on-policy"),
                              (SimMode::SortedPartial, "partial"),
                              (SimMode::Async, "async")] {
            // the 1-engine comparison leg keeps uniform lanes: per-engine
            // specs only make sense for the N-engine side
            let one = SimRun::new(mode, PoolSimOpts { engines: 1, ..opts })
                .workload(&w)
                .run();
            let many = SimRun::new(mode, opts).workload(&w).specs(&specs).run();
            if mode == SimMode::SortedPartial {
                telemetry = (many.predictor_mae, many.predictor_tau);
                kv_stats = (many.peak_lanes, many.kv_sheds, many.throttles);
                tail_stats = (many.tail_rounds, many.tail_admitted,
                              many.repartitions, many.head_bubble,
                              many.tail_bubble);
            }
            // report steal stats from the unsorted baseline: sorted modes
            // already balance the tail and steal ~never
            if mode == SimMode::Baseline {
                stolen = (many.steals, many.migrated_tokens);
            }
            if mode == SimMode::Async {
                stale = (many.max_staleness, many.stale_resyncs);
            }
            println!("{label:>10}: bubble {:5.2}% -> {:5.2}%   tok/s {:7.0} -> {:7.0}   \
                      rollout {:6.1}s -> {:6.1}s",
                     one.bubble_ratio * 100.0, many.bubble_ratio * 100.0,
                     one.throughput, many.throughput,
                     one.rollout_time, many.rollout_time);
        }
        println!("predictor {} (partial, {engines} engines): MAE {:.1} tokens, \
                  Kendall tau {:.3}",
                 predictor.name(), telemetry.0, telemetry.1);
        if steal {
            println!("work stealing (baseline, {engines} engines): {} steals, \
                      {} in-flight tokens migrated",
                     stolen.0, stolen.1);
        }
        if kv.budget != usize::MAX {
            println!("kv {} (partial, {engines} engines, budget {} page {}): \
                      peak lanes {}, {} forced sheds, {} throttles",
                     kv.mode.name(), kv.budget, kv.page,
                     kv_stats.0, kv_stats.1, kv_stats.2);
        }
        if let Some(n) = staleness {
            println!("staleness cap {n} (async, {engines} engines): \
                      max consumed {}, {} re-syncs",
                     stale.0, stale.1);
        }
        if let Some(tc) = tail {
            println!("tail packing (partial, threshold {} tokens, {} tail \
                      engine(s)): {} rounds, {} requests packed, {} \
                      repartitions; head bubble {:.2}% tail bubble {:.2}%",
                     tc.threshold, tc.tail_engines, tail_stats.0,
                     tail_stats.1, tail_stats.2,
                     tail_stats.3 * 100.0, tail_stats.4 * 100.0);
        }
    } else {
        println!("\n(pass --engines N to compare 1-engine vs N-engine pools)");
    }
    let (trace_out, slo_ms) = parse_tracing(args)?;
    if args.get("slo-out").is_some() && slo_ms.is_none() {
        bail!("--slo-out needs --slo MS to define the goodput target");
    }
    if trace_out.is_some() || slo_ms.is_some() {
        // trace the partial-rollout scheduler (the paper's headline mode)
        // through the same pool the comparison above ran
        let slo_secs = slo_ms.map(|ms| ms / 1000.0);
        let mut tracer = sortedrl::trace::Tracer::new(slo_secs, trace_out.is_some());
        let r = SimRun::new(SimMode::SortedPartial, opts)
            .workload(&w)
            .specs(&specs)
            .tracer(&mut tracer)
            .run();
        let s = &r.slo;
        println!("\nslo (partial, {engines} engine(s){}):",
                 match slo_ms {
                     Some(ms) => format!(", target {ms:.0} ms"),
                     None => String::new(),
                 });
        println!("  requests: {} enqueued, {} completed, {} clipped, {} dropped",
                 s.enqueued, s.completed, s.clipped, s.dropped);
        println!("  ttft  p50 {:8.3}s  p90 {:8.3}s  p99 {:8.3}s",
                 s.ttft_p50, s.ttft_p90, s.ttft_p99);
        println!("  tpot  p50 {:8.4}s  p90 {:8.4}s  p99 {:8.4}s",
                 s.tpot_p50, s.tpot_p90, s.tpot_p99);
        println!("  e2e   p50 {:8.3}s  p99 {:8.3}s   queue-wait p99 {:.3}s",
                 s.e2e_p50, s.e2e_p99, s.queue_p99);
        if slo_ms.is_some() {
            println!("  goodput {:.3} ({} of {} within SLO)",
                     s.goodput,
                     (s.goodput * s.enqueued as f64).round() as u64, s.enqueued);
        }
        if let Some(path) = args.get("slo-out") {
            std::fs::write(path, s.to_json().to_string_pretty())
                .with_context(|| format!("writing {path}"))?;
            println!("  wrote SLO summary JSON to {path}");
        }
        if let Some(path) = &trace_out {
            tracer.write_chrome(path)?;
            println!("  wrote {} trace events to {} (open at https://ui.perfetto.dev)",
                     tracer.chrome_events(), path.display());
        }
    }
    if let Some(path) = args.get("report-out") {
        // one partial-mode pool run with every knob applied, dumped as a
        // flat JSON object (CI greps the tail/bubble keys out of this file)
        let r = SimRun::new(SimMode::SortedPartial, opts)
            .workload(&w)
            .specs(&specs)
            .run();
        let json = obj(vec![
            ("throughput", num(r.throughput)),
            ("bubble_ratio", num(r.bubble_ratio)),
            ("rollout_time", num(r.rollout_time)),
            ("total_time", num(r.total_time)),
            ("wasted_tokens", num(r.wasted_tokens as f64)),
            ("clipped", num(r.clipped as f64)),
            ("steals", num(r.steals as f64)),
            ("kv_sheds", num(r.kv_sheds as f64)),
            ("throttles", num(r.throttles as f64)),
            ("tail_rounds", num(r.tail_rounds as f64)),
            ("tail_admitted", num(r.tail_admitted as f64)),
            ("repartitions", num(r.repartitions as f64)),
            ("head_bubble", num(r.head_bubble)),
            ("tail_bubble", num(r.tail_bubble)),
        ]);
        std::fs::write(path, json.to_string_pretty())
            .with_context(|| format!("writing {path}"))?;
        println!("\nwrote partial-mode pool report JSON to {path}");
    }
    Ok(())
}

/// The open-loop `sim` section: run every scheduler mode over the arrival
/// stream, then (with tracing flags) a recorded partial-mode run that
/// reports arrival-relative latencies, per-tenant rollups, and fairness.
fn sim_open_loop(args: &Args, arrivals: &[Arrival], cap: usize, q: usize, u: usize,
                 opts: PoolSimOpts, specs: &[EngineSpec]) -> Result<()> {
    if arrivals.is_empty() {
        bail!("--arrival produced an empty stream");
    }
    let span = arrivals.last().unwrap().t - arrivals[0].t;
    let tenants = arrivals.iter().map(|a| a.tenant).max().unwrap_or(0) + 1;
    println!("workload: {} open-loop arrivals over {span:.1}s ({tenants} tenant(s)), \
              cap {cap}, queue {q}, update batch {u}\n", arrivals.len());
    for (mode, label) in [(SimMode::Baseline, "baseline"),
                          (SimMode::SortedOnPolicy, "on-policy"),
                          (SimMode::SortedPartial, "partial"),
                          (SimMode::Async, "async")] {
        let r = SimRun::new(mode, opts).arrivals(arrivals).specs(specs).run();
        println!("{label:>10}: {:7.0} tok/s  bubble {:5.2}%  rollout {:7.1}s  \
                  total {:7.1}s  clipped {:3}  dropped {:3}",
                 r.throughput, r.bubble_ratio * 100.0, r.rollout_time,
                 r.total_time, r.clipped, r.dropped);
    }
    let (trace_out, slo_ms) = parse_tracing(args)?;
    if args.get("slo-out").is_some() && slo_ms.is_none() {
        bail!("--slo-out needs --slo MS to define the goodput target");
    }
    if trace_out.is_some() || slo_ms.is_some() {
        let slo_secs = slo_ms.map(|ms| ms / 1000.0);
        let mut tracer = sortedrl::trace::Tracer::new(slo_secs, trace_out.is_some());
        let r = SimRun::new(SimMode::SortedPartial, opts)
            .arrivals(arrivals)
            .specs(specs)
            .tracer(&mut tracer)
            .run();
        let s = &r.slo;
        println!("\nslo (partial, {} engine(s), arrival-relative{}):",
                 opts.engines,
                 match slo_ms {
                     Some(ms) => format!(", target {ms:.0} ms"),
                     None => String::new(),
                 });
        println!("  requests: {} enqueued, {} completed, {} clipped, {} dropped",
                 s.enqueued, s.completed, s.clipped, s.dropped);
        println!("  ttft  p50 {:8.3}s  p90 {:8.3}s  p99 {:8.3}s",
                 s.ttft_p50, s.ttft_p90, s.ttft_p99);
        println!("  e2e   p50 {:8.3}s  p99 {:8.3}s   queue-wait p99 {:.3}s",
                 s.e2e_p50, s.e2e_p99, s.queue_p99);
        if slo_ms.is_some() {
            println!("  goodput {:.3} ({} of {} within SLO)",
                     s.goodput,
                     (s.goodput * s.enqueued as f64).round() as u64, s.enqueued);
        }
        if !s.tenants.is_empty() {
            println!("  tenants (Jain fairness {:.3}):", s.fairness_jain);
            for t in &s.tenants {
                println!("    t{}: {:5} enq {:5} done  ttft p50 {:7.3}s  \
                          e2e p50 {:7.3}s p99 {:7.3}s  goodput {:.3}",
                         t.tenant, t.enqueued, t.completed, t.ttft_p50,
                         t.e2e_p50, t.e2e_p99, t.goodput);
            }
        }
        if let Some((t, d)) = s.queue_depth.iter().max_by_key(|(_, d)| *d) {
            println!("  peak queue depth {d} at t={t:.1}s \
                      ({} samples)", s.queue_depth.len());
        }
        if let Some(path) = args.get("slo-out") {
            std::fs::write(path, s.to_json().to_string_pretty())
                .with_context(|| format!("writing {path}"))?;
            println!("  wrote per-tenant SLO summary JSON to {path}");
        }
        if let Some(path) = &trace_out {
            tracer.write_chrome(path)?;
            println!("  wrote {} trace events to {} (open at https://ui.perfetto.dev)",
                     tracer.chrome_events(), path.display());
        }
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let tags = sortedrl::runtime::manifest::Manifest::list_tags(&dir)?;
    println!("artifact configs in {}:", dir.display());
    for t in &tags {
        println!("  {t}");
    }
    if let Ok(rt) = Runtime::load(&dir, args.get("tag")) {
        let m = &rt.manifest;
        println!("\nloaded tag: {}", m.tag);
        println!("platform:   {}", rt.platform());
        println!("model:      d={} L={} H={} ff={} S={} V={} ({} params)",
                 m.model.d_model, m.model.n_layers, m.model.n_heads,
                 m.model.d_ff, m.model.max_seq, m.model.vocab,
                 m.model.param_count);
        println!("shapes:     engine B={} chunk k={} train Bt={} T={}",
                 m.shapes.engine_batch, m.shapes.decode_chunk,
                 m.shapes.train_batch, m.shapes.train_seq);
        println!("kernels:    pallas={}", m.use_pallas);
    }
    Ok(())
}
