//! PJRT runtime: load AOT artifacts, hold device executables, and expose
//! typed entry points (`init` / `prefill` / `decode_chunk` / `train_step` /
//! `sft_step` / `logprob`) to the coordinator.
//!
//! Python never runs here — the HLO text in `artifacts/` is the entire
//! model.  Pattern follows /opt/xla-example/load_hlo (HLO text in,
//! `PjRtClient::cpu()` compile, literal marshaling per manifest).

pub mod manifest;

use crate::tokenizer::Tokenizer;
use anyhow::{bail, Context, Result};
use manifest::{EntrySpec, Manifest};
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Cumulative wall-time accounting per entry point (perf + Fig.1a).
#[derive(Debug, Clone, Copy, Default)]
pub struct RuntimeStats {
    pub prefill_calls: u64,
    pub prefill_secs: f64,
    pub decode_calls: u64,
    pub decode_secs: f64,
    pub train_calls: u64,
    pub train_secs: f64,
    pub sft_calls: u64,
    pub sft_secs: f64,
    pub logprob_calls: u64,
    pub logprob_secs: f64,
}

/// Model parameters + Adam state, owned as host literals between steps.
/// `Clone` is the async pipeline's weight hand-off: the trainer thread
/// owns the master copy and ships a snapshot back per update for serving.
#[derive(Clone)]
pub struct ParamState {
    pub params: Vec<Literal>,
    pub m: Vec<Literal>,
    pub v: Vec<Literal>,
    pub step: i32,
    /// Monotone policy version: bumped on every successful train/sft step.
    pub version: u64,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct TrainStats {
    pub loss: f32,
    pub mean_ratio: f32,
    pub clip_frac: f32,
    pub mean_entropy: f32,
    pub approx_kl: f32,
    pub grad_norm: f32,
}

/// Inputs to one train_step call (shapes per manifest: [Bt, T] row-major).
pub struct TrainBatch {
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
    pub adv: Vec<f32>,
    pub old_logp: Vec<f32>,
    pub lr: f32,
}

/// Outputs of one decode_chunk call.
pub struct DecodeOut {
    pub tok: Vec<i32>,
    pub pos: Vec<i32>,
    pub active: Vec<i32>,
    /// [B, k] row-major.
    pub out_tokens: Vec<i32>,
    pub out_logp: Vec<f32>,
}

pub struct Runtime {
    pub manifest: Manifest,
    client: PjRtClient,
    init_exe: PjRtLoadedExecutable,
    prefill_exe: PjRtLoadedExecutable,
    decode_exe: PjRtLoadedExecutable,
    train_exe: PjRtLoadedExecutable,
    sft_exe: PjRtLoadedExecutable,
    logprob_exe: PjRtLoadedExecutable,
    pub stats: Mutex<RuntimeStats>,
}

fn compile(client: &PjRtClient, e: &EntrySpec) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(&e.file)
        .with_context(|| format!("parsing {}", e.file.display()))?;
    client
        .compile(&XlaComputation::from_proto(&proto))
        .with_context(|| format!("compiling {}", e.file.display()))
}

impl Runtime {
    /// Load + compile every entry point of config `tag` under `dir`.
    pub fn load(dir: &Path, tag: Option<&str>) -> Result<Self> {
        let manifest = Manifest::load(dir, tag)?;
        // Fail fast if the tokenizer drifted from the build-time vocab.
        Tokenizer::new()
            .assert_matches_manifest(&manifest.vocab)
            .map_err(|e| anyhow::anyhow!(e))?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            init_exe: compile(&client, &manifest.init)?,
            prefill_exe: compile(&client, &manifest.prefill)?,
            decode_exe: compile(&client, &manifest.decode_chunk)?,
            train_exe: compile(&client, &manifest.train_step)?,
            sft_exe: compile(&client, &manifest.sft_step)?,
            logprob_exe: compile(&client, &manifest.logprob)?,
            manifest,
            client,
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn n_params(&self) -> usize {
        self.manifest.shapes.n_param_tensors
    }

    /// Execute and unpack the single tuple output into literals.
    fn run(exe: &PjRtLoadedExecutable, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let res = exe.execute::<&Literal>(inputs)?;
        let lit = res[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    // ----------------------------------------------------------------
    // init
    // ----------------------------------------------------------------

    /// Fresh parameters + zeroed Adam state from an i32 seed.
    pub fn init(&self, seed: i32) -> Result<ParamState> {
        let seed_lit = Literal::scalar(seed);
        let params = Self::run(&self.init_exe, &[&seed_lit])?;
        if params.len() != self.n_params() {
            bail!("init returned {} tensors, manifest says {}", params.len(), self.n_params());
        }
        let zeros = |spec: &[manifest::TensorSpec]| -> Vec<Literal> {
            spec.iter()
                .map(|t| {
                    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                    Literal::vec1(&vec![0f32; t.elements()])
                        .reshape(&dims)
                        .expect("zero literal")
                })
                .collect()
        };
        Ok(ParamState {
            m: zeros(&self.manifest.params),
            v: zeros(&self.manifest.params),
            params,
            step: 0,
            version: 0,
        })
    }

    // ----------------------------------------------------------------
    // prefill
    // ----------------------------------------------------------------

    /// Prompt (or prompt+resume) ingestion for ALL engine lanes at once.
    /// `tokens` is [B, Sp] row-major, `length[b]` the valid prefix length.
    /// Returns the new KV cache (caller-owned — the engine holds it) and
    /// the last-position logits per lane ([B, V] row-major).
    pub fn prefill(&self, state: &ParamState, tokens: &[i32], length: &[i32])
                   -> Result<(Literal, Vec<f32>)> {
        let sh = &self.manifest.shapes;
        let b = sh.engine_batch;
        assert_eq!(tokens.len(), b * sh.prefill_seq);
        assert_eq!(length.len(), b);
        let t0 = Instant::now();
        let tok_lit = Literal::vec1(tokens)
            .reshape(&[b as i64, sh.prefill_seq as i64])?;
        let len_lit = Literal::vec1(length);
        let mut inputs: Vec<&Literal> = state.params.iter().collect();
        inputs.push(&tok_lit);
        inputs.push(&len_lit);
        let mut outs = Self::run(&self.prefill_exe, &inputs)?;
        let logits = outs.pop().context("prefill logits")?;
        let kv = outs.pop().context("prefill kv")?;
        let out = logits.to_vec::<f32>()?;
        let mut st = self.stats.lock().unwrap();
        st.prefill_calls += 1;
        st.prefill_secs += t0.elapsed().as_secs_f64();
        Ok((kv, out))
    }

    /// Overwrite lanes `lanes` of `old_kv` with the same lanes of `fresh` —
    /// used when admitting new requests into free lanes while other lanes
    /// are mid-generation (continuous batching).
    ///
    /// Layout: kv f32[NL, 2, B, H, S, Dh]; a lane is strided — one
    /// contiguous block of H*S*Dh floats per (layer, k/v) slice.
    pub fn merge_kv_lanes(&self, old_kv: &Literal, fresh: &Literal, lanes: &[usize])
                          -> Result<Literal> {
        let dims = &self.manifest.shapes.kv_cache;
        let (nl, two, b) = (dims[0], dims[1], dims[2]);
        let lane_block = dims[3] * dims[4] * dims[5];
        let mut data = old_kv.to_vec::<f32>()?;
        let fresh_data = fresh.to_vec::<f32>()?;
        for outer in 0..nl * two {
            let base = outer * b * lane_block;
            for &lane in lanes {
                let off = base + lane * lane_block;
                data[off..off + lane_block]
                    .copy_from_slice(&fresh_data[off..off + lane_block]);
            }
        }
        let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(Literal::vec1(&data).reshape(&dims_i)?)
    }

    // ----------------------------------------------------------------
    // decode
    // ----------------------------------------------------------------

    /// One chunk of k decode steps for the whole engine batch. Consumes the
    /// caller's KV cache and returns the updated one.  `uniforms` is [B, k]
    /// in [0,1) (negative = greedy); sampling happens inside the HLO (L2),
    /// so the returned `out_logp` are the exact behavior-policy log-probs.
    pub fn decode_chunk(&self, state: &ParamState, kv: Literal, tok: &[i32],
                        pos: &[i32], active: &[i32], uniforms: &[f32],
                        temp: f32) -> Result<(Literal, DecodeOut)> {
        let sh = &self.manifest.shapes;
        let (b, k) = (sh.engine_batch, sh.decode_chunk);
        assert_eq!(tok.len(), b);
        assert_eq!(uniforms.len(), b * k);
        let t0 = Instant::now();
        let tok_lit = Literal::vec1(tok);
        let pos_lit = Literal::vec1(pos);
        let act_lit = Literal::vec1(active);
        let uni_lit = Literal::vec1(uniforms).reshape(&[b as i64, k as i64])?;
        let temp_lit = Literal::scalar(temp);
        let mut inputs: Vec<&Literal> = state.params.iter().collect();
        inputs.extend([&kv, &tok_lit, &pos_lit, &act_lit, &uni_lit, &temp_lit]);
        let mut outs = Self::run(&self.decode_exe, &inputs)?;
        // outputs: kv, tok, pos, active, out_tokens, out_logp
        let out_logp = outs.pop().context("out_logp")?.to_vec::<f32>()?;
        let out_tokens = outs.pop().context("out_tokens")?.to_vec::<i32>()?;
        let active = outs.pop().context("active")?.to_vec::<i32>()?;
        let pos = outs.pop().context("pos")?.to_vec::<i32>()?;
        let tok = outs.pop().context("tok")?.to_vec::<i32>()?;
        let new_kv = outs.pop().context("kv")?;
        let mut st = self.stats.lock().unwrap();
        st.decode_calls += 1;
        st.decode_secs += t0.elapsed().as_secs_f64();
        Ok((new_kv, DecodeOut { tok, pos, active, out_tokens, out_logp }))
    }

    // ----------------------------------------------------------------
    // training
    // ----------------------------------------------------------------

    /// One PPO update; swaps params/adam state in place and bumps version.
    pub fn train_step(&self, state: &mut ParamState, batch: &TrainBatch)
                      -> Result<TrainStats> {
        let sh = &self.manifest.shapes;
        let (bt, t) = (sh.train_batch, sh.train_seq);
        assert_eq!(batch.tokens.len(), bt * t);
        let t0 = Instant::now();
        let n = self.n_params();
        let step_lit = Literal::scalar(state.step);
        let tok_lit = Literal::vec1(&batch.tokens).reshape(&[bt as i64, t as i64])?;
        let mask_lit = Literal::vec1(&batch.mask).reshape(&[bt as i64, t as i64])?;
        let adv_lit = Literal::vec1(&batch.adv).reshape(&[bt as i64, t as i64])?;
        let lp_lit = Literal::vec1(&batch.old_logp).reshape(&[bt as i64, t as i64])?;
        let lr_lit = Literal::scalar(batch.lr);
        let mut inputs: Vec<&Literal> = Vec::with_capacity(3 * n + 6);
        inputs.extend(state.params.iter());
        inputs.extend(state.m.iter());
        inputs.extend(state.v.iter());
        inputs.extend([&step_lit, &tok_lit, &mask_lit, &adv_lit, &lp_lit, &lr_lit]);
        let mut outs = Self::run(&self.train_exe, &inputs)?;
        // outputs: params*n, m*n, v*n, step, loss, ratio, clipf, ent, kl, gnorm
        let gnorm = outs.pop().unwrap().get_first_element::<f32>()?;
        let kl = outs.pop().unwrap().get_first_element::<f32>()?;
        let ent = outs.pop().unwrap().get_first_element::<f32>()?;
        let clipf = outs.pop().unwrap().get_first_element::<f32>()?;
        let ratio = outs.pop().unwrap().get_first_element::<f32>()?;
        let loss = outs.pop().unwrap().get_first_element::<f32>()?;
        let step = outs.pop().unwrap().get_first_element::<i32>()?;
        let v = outs.split_off(2 * n);
        let m = outs.split_off(n);
        state.params = outs;
        state.m = m;
        state.v = v;
        state.step = step;
        state.version += 1;
        let mut st = self.stats.lock().unwrap();
        st.train_calls += 1;
        st.train_secs += t0.elapsed().as_secs_f64();
        Ok(TrainStats {
            loss,
            mean_ratio: ratio,
            clip_frac: clipf,
            mean_entropy: ent,
            approx_kl: kl,
            grad_norm: gnorm,
        })
    }

    /// One supervised step (warm start); `weights` is the loss mask.
    pub fn sft_step(&self, state: &mut ParamState, tokens: &[i32], weights: &[f32],
                    lr: f32) -> Result<(f32, f32)> {
        let sh = &self.manifest.shapes;
        let (bt, t) = (sh.train_batch, sh.train_seq);
        assert_eq!(tokens.len(), bt * t);
        let t0 = Instant::now();
        let n = self.n_params();
        let step_lit = Literal::scalar(state.step);
        let tok_lit = Literal::vec1(tokens).reshape(&[bt as i64, t as i64])?;
        let w_lit = Literal::vec1(weights).reshape(&[bt as i64, t as i64])?;
        let lr_lit = Literal::scalar(lr);
        let mut inputs: Vec<&Literal> = Vec::with_capacity(3 * n + 4);
        inputs.extend(state.params.iter());
        inputs.extend(state.m.iter());
        inputs.extend(state.v.iter());
        inputs.extend([&step_lit, &tok_lit, &w_lit, &lr_lit]);
        let mut outs = Self::run(&self.sft_exe, &inputs)?;
        let gnorm = outs.pop().unwrap().get_first_element::<f32>()?;
        let loss = outs.pop().unwrap().get_first_element::<f32>()?;
        let step = outs.pop().unwrap().get_first_element::<i32>()?;
        let v = outs.split_off(2 * n);
        let m = outs.split_off(n);
        state.params = outs;
        state.m = m;
        state.v = v;
        state.step = step;
        state.version += 1;
        let mut st = self.stats.lock().unwrap();
        st.sft_calls += 1;
        st.sft_secs += t0.elapsed().as_secs_f64();
        Ok((loss, gnorm))
    }

    /// Per-token log-probs of `tokens` ([Bt, T] row-major) under `state`.
    pub fn logprob(&self, state: &ParamState, tokens: &[i32]) -> Result<Vec<f32>> {
        let sh = &self.manifest.shapes;
        let (bt, t) = (sh.train_batch, sh.train_seq);
        assert_eq!(tokens.len(), bt * t);
        let t0 = Instant::now();
        let tok_lit = Literal::vec1(tokens).reshape(&[bt as i64, t as i64])?;
        let mut inputs: Vec<&Literal> = state.params.iter().collect();
        inputs.push(&tok_lit);
        let outs = Self::run(&self.logprob_exe, &inputs)?;
        let lp = outs[0].to_vec::<f32>()?;
        let mut st = self.stats.lock().unwrap();
        st.logprob_calls += 1;
        st.logprob_secs += t0.elapsed().as_secs_f64();
        Ok(lp)
    }

    pub fn stats_snapshot(&self) -> RuntimeStats {
        *self.stats.lock().unwrap()
    }
}
