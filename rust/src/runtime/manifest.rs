//! artifacts/manifest.json parsing — the calling-convention contract
//! emitted by python/compile/aot.py.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j.get("name").and_then(Json::as_str).context("tensor name")?.into(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .context("tensor shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<_>>()?,
            dtype: DType::parse(j.get("dtype").and_then(Json::as_str).context("dtype")?)?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256: String,
}

impl EntrySpec {
    fn parse(dir: &Path, j: &Json) -> Result<Self> {
        Ok(EntrySpec {
            file: dir.join(j.get("file").and_then(Json::as_str).context("entry file")?),
            inputs: j
                .get("inputs")
                .and_then(Json::as_arr)
                .context("inputs")?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<_>>()?,
            outputs: j
                .get("outputs")
                .and_then(Json::as_arr)
                .context("outputs")?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<_>>()?,
            sha256: j.get("sha256").and_then(Json::as_str).unwrap_or("").into(),
        })
    }
}

/// Shape constants baked into one artifact config.
#[derive(Debug, Clone)]
pub struct Shapes {
    pub engine_batch: usize,
    pub decode_chunk: usize,
    pub train_batch: usize,
    pub train_seq: usize,
    pub prefill_seq: usize,
    pub n_param_tensors: usize,
    pub kv_cache: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub vocab: usize,
    pub param_count: usize,
}

/// One compiled artifact config ("tag") from the manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub tag: String,
    pub preset: String,
    pub model: ModelInfo,
    pub shapes: Shapes,
    pub vocab: Vec<String>,
    pub use_pallas: bool,
    pub params: Vec<TensorSpec>,
    pub init: EntrySpec,
    pub prefill: EntrySpec,
    pub decode_chunk: EntrySpec,
    pub train_step: EntrySpec,
    pub sft_step: EntrySpec,
    pub logprob: EntrySpec,
}

impl Manifest {
    /// Load the config `tag` from `dir/manifest.json`; with `tag == None`
    /// the manifest must contain exactly one config.
    pub fn load(dir: &Path, tag: Option<&str>) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let configs = j.get("configs").and_then(Json::as_obj).context("configs")?;
        let cfg = match tag {
            Some(t) => configs
                .get(t)
                .ok_or_else(|| anyhow!("tag {t:?} not in manifest (have: {:?})",
                                       configs.keys().collect::<Vec<_>>()))?,
            None => {
                if configs.len() == 1 {
                    configs.values().next().unwrap()
                } else if let Some(preferred) = ["mini", "small"]
                    .iter()
                    .find_map(|want| {
                        configs.iter().find(|(_, c)| {
                            c.get("preset").and_then(Json::as_str) == Some(want)
                        })
                    })
                    .map(|(_, c)| c)
                {
                    // multiple configs: prefer the single-core-friendly
                    // "mini" preset, then "small" (tiny is the test config)
                    preferred
                } else {
                    bail!(
                        "manifest has {} configs, pass --tag (have: {:?})",
                        configs.len(),
                        configs.keys().collect::<Vec<_>>()
                    );
                }
            }
        };
        Self::parse(dir, cfg)
    }

    fn parse(dir: &Path, j: &Json) -> Result<Self> {
        let sh = j.get("shapes").context("shapes")?;
        let get = |o: &Json, k: &str| -> Result<usize> {
            o.get(k).and_then(Json::as_usize).with_context(|| format!("shapes.{k}"))
        };
        let shapes = Shapes {
            engine_batch: get(sh, "engine_batch")?,
            decode_chunk: get(sh, "decode_chunk")?,
            train_batch: get(sh, "train_batch")?,
            train_seq: get(sh, "train_seq")?,
            prefill_seq: get(sh, "prefill_seq")?,
            n_param_tensors: get(sh, "n_param_tensors")?,
            kv_cache: sh
                .get("kv_cache")
                .and_then(Json::as_arr)
                .context("kv_cache")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<_>>()?,
        };
        let m = j.get("model").context("model")?;
        let model = ModelInfo {
            d_model: get(m, "d_model")?,
            n_layers: get(m, "n_layers")?,
            n_heads: get(m, "n_heads")?,
            d_ff: get(m, "d_ff")?,
            max_seq: get(m, "max_seq")?,
            vocab: get(m, "vocab")?,
            param_count: get(m, "param_count")?,
        };
        let entries = j.get("entries").context("entries")?;
        let entry = |name: &str| -> Result<EntrySpec> {
            EntrySpec::parse(dir, entries.get(name).with_context(|| format!("entry {name}"))?)
        };
        Ok(Manifest {
            tag: j.get("tag").and_then(Json::as_str).context("tag")?.into(),
            preset: j.get("preset").and_then(Json::as_str).context("preset")?.into(),
            model,
            shapes,
            vocab: j
                .get("vocab")
                .and_then(Json::as_arr)
                .context("vocab")?
                .iter()
                .map(|v| v.as_str().map(String::from).context("vocab entry"))
                .collect::<Result<_>>()?,
            use_pallas: j.get("use_pallas").and_then(Json::as_bool).unwrap_or(true),
            params: j
                .get("params")
                .and_then(Json::as_arr)
                .context("params")?
                .iter()
                .map(|p| {
                    Ok(TensorSpec {
                        name: p.get("name").and_then(Json::as_str).context("name")?.into(),
                        shape: p
                            .get("shape")
                            .and_then(Json::as_arr)
                            .context("shape")?
                            .iter()
                            .map(|d| d.as_usize().context("dim"))
                            .collect::<Result<_>>()?,
                        dtype: DType::F32,
                    })
                })
                .collect::<Result<_>>()?,
            init: entry("init")?,
            prefill: entry("prefill")?,
            decode_chunk: entry("decode_chunk")?,
            train_step: entry("train_step")?,
            sft_step: entry("sft_step")?,
            logprob: entry("logprob")?,
        })
    }

    /// List available tags without fully parsing.
    pub fn list_tags(dir: &Path) -> Result<Vec<String>> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text)?;
        Ok(j.get("configs")
            .and_then(Json::as_obj)
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default())
    }
}
